#!/usr/bin/env sh
# Tier-1 verify: run the suite from anywhere (pyproject pins pythonpath=src).
# exec replaces the shell, so the script exits with pytest's own status code.
set -e
cd "$(dirname "$0")/.."
exec python -m pytest -x -q "$@"
