#!/usr/bin/env sh
# Tier-1 verify: run the suite from anywhere (pyproject pins pythonpath=src).
set -e
cd "$(dirname "$0")/.."
exec python -m pytest -x -q "$@"
