"""Optimizers + learning-rate schedules for the FL runtime.

The paper trains with plain SGD at the clients (Eq. 2) and the server applies
the aggregated update directly (FedAvg is SGD with the aggregated gradient).
``sgd``/``momentum`` cover the server-side update of the big-arch federated
step; schedules reproduce the paper's inverse-decay and constant profiles.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

__all__ = ["sgd", "momentum", "inverse_decay", "constant_lr", "Optimizer",
           "clip_by_global_norm"]


class Optimizer(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree, jnp.ndarray], tuple]
    name: str


def sgd() -> Optimizer:
    """w <- w - eta * g (stateless)."""

    def init(params):
        return ()

    def update(grads, state, params, eta):
        new = jax.tree.map(
            lambda w, g: (w.astype(jnp.float32)
                          - eta * g.astype(jnp.float32)).astype(w.dtype),
            params, grads)
        return new, state

    return Optimizer(init, update, "sgd")


def momentum(beta: float = 0.9) -> Optimizer:
    """Polyak momentum: v <- beta v + g; w <- w - eta v."""

    def init(params):
        return jax.tree.map(lambda w: jnp.zeros(w.shape, jnp.float32), params)

    def update(grads, state, params, eta):
        v = jax.tree.map(lambda s, g: beta * s + g.astype(jnp.float32),
                         state, grads)
        new = jax.tree.map(
            lambda w, vv: (w.astype(jnp.float32) - eta * vv).astype(w.dtype),
            params, v)
        return new, v

    return Optimizer(init, update, f"momentum{beta}")


def clip_by_global_norm(grads: PyTree, max_norm: float) -> PyTree:
    sq = sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads))
    norm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)


def inverse_decay(eta0: float, R: int) -> np.ndarray:
    """eta_t = eta0 / (1 + t), the paper's schedule (satisfies eta_t <= 2 eta_{t+1})."""
    t = np.arange(1, R + 1, dtype=np.float32)
    return (eta0 / (1.0 + t)).astype(np.float32)


def constant_lr(eta0: float, R: int) -> np.ndarray:
    return np.full((R,), eta0, np.float32)
