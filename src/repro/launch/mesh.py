"""Production mesh construction (TPU v5e target).

Defined as FUNCTIONS so importing this module never touches jax device
state; ``dryrun.py`` sets ``XLA_FLAGS=--xla_force_host_platform_device_count``
BEFORE importing jax.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "batch_axes", "mesh_chips"]


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips, axes (data, model).
    Multi-pod: 2x16x16 = 512 chips, axes (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def batch_axes(mesh) -> tuple:
    """The axes the (client/batch) dimension shards over."""
    names = mesh.axis_names
    return ("pod", "data") if "pod" in names else ("data",)


def mesh_chips(mesh) -> int:
    return mesh.devices.size
