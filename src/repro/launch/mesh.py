"""Production mesh construction (TPU v5e target).

Defined as FUNCTIONS so importing this module never touches jax device
state; ``dryrun.py`` sets ``XLA_FLAGS=--xla_force_host_platform_device_count``
BEFORE importing jax.
"""
from __future__ import annotations

import jax
import numpy as np

__all__ = ["make_production_mesh", "make_client_mesh", "batch_axes",
           "mesh_chips", "batch_shards"]


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips, axes (data, model).
    Multi-pod: 2x16x16 = 512 chips, axes (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_client_mesh(n_devices: int | None = None):
    """1-D mesh over the available devices with the axis named ``data`` so
    :func:`batch_axes` treats it exactly like the production data axis.

    This is the mesh ``repro.fl.backends.ShardMapBackend`` uses by default:
    the federated client axis becomes a real mesh axis. On a CPU host, set
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (before importing
    jax) to get N shards.
    """
    devs = jax.devices()
    n = len(devs) if n_devices is None else min(int(n_devices), len(devs))
    return jax.sharding.Mesh(np.asarray(devs[:n]), ("data",))


def batch_axes(mesh) -> tuple:
    """The axes the (client/batch) dimension shards over."""
    names = mesh.axis_names
    return ("pod", "data") if "pod" in names else ("data",)


def batch_shards(mesh) -> int:
    """Number of shards the client/batch dimension splits into."""
    shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for ax in batch_axes(mesh):
        n *= shape[ax]
    return n


def mesh_chips(mesh) -> int:
    return mesh.devices.size
