"""Batched serving driver: prefill a batch of prompts, then greedy-decode.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --batch 4 \
        --prompt-len 32 --new-tokens 32
"""
from __future__ import annotations

import argparse

import jax

from repro.obs import now
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.steps import make_serve_step
from repro.models import transformer as tr


def serve(arch: str, *, batch: int = 4, prompt_len: int = 32,
          new_tokens: int = 32, seed: int = 0, reduced: bool = True,
          verbose: bool = True) -> dict:
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(seed)
    key, k_init, k_prompt = jax.random.split(key, 3)
    params = tr.init_params(k_init, cfg)
    prompts = jax.random.randint(k_prompt, (batch, prompt_len), 0, cfg.vocab)

    max_seq = prompt_len + new_tokens
    cache = tr.init_cache(cfg, batch, max_seq, dtype=jnp.float32)
    if cfg.enc_layers:
        frames = 0.02 * jax.random.normal(
            key, (batch, cfg.n_frontend_tokens, cfg.d_model))
        enc_out = tr._run_encoder(params, cfg, frames, jnp.dtype(cfg.dtype))
        cache = cache._replace(cross=tr.build_cross_cache(params, cfg, enc_out))

    step = jax.jit(make_serve_step(cfg))

    # prefill by stepping the prompt through the decode path (cache fill);
    # production prefill is the batched forward (see launch/specs.py)
    t0 = now()
    tok = prompts[:, 0]
    for i in range(prompt_len - 1):
        _, cache = step(params, cache, prompts[:, i], jnp.int32(i))
    t_prefill = now() - t0

    out = [prompts[:, -1]]
    t0 = now()
    pos = prompt_len - 1
    tok = prompts[:, -1]
    for j in range(new_tokens):
        tok, cache = step(params, cache, tok, jnp.int32(pos + j))
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = now() - t0
    gen = np.stack([np.asarray(t) for t in out[1:]], axis=1)
    tps = batch * new_tokens / max(t_decode, 1e-9)
    if verbose:
        print(f"[serve] {cfg.name}: prefill {prompt_len} toks in "
              f"{t_prefill:.2f}s; decoded {new_tokens} x {batch} in "
              f"{t_decode:.2f}s ({tps:.1f} tok/s)")
        print(f"[serve] sample continuation: {gen[0, :16].tolist()}")
    return {"arch": cfg.name, "tok_per_s": tps, "generated": gen.tolist()}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)
    serve(args.arch, batch=args.batch, prompt_len=args.prompt_len,
          new_tokens=args.new_tokens, seed=args.seed,
          reduced=not args.full)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
