import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Exact roofline-cost reconstruction for the dry-run records.

XLA's HloCostAnalysis counts a while-loop body ONCE regardless of trip
count, so the scan-form dry-run modules (lax.scan over L stacked blocks and,
for train, over U clients) undercount FLOPs / bytes / collective bytes by
~L (and ~U*L for train). Fully unrolling the production-depth module is
exact but compiles for >10 min per combo on this container.

Instead we lower REDUCED-DEPTH, FULLY-UNROLLED probes (no while loops at
all, so HloCostAnalysis is exact) and reconstruct the production cost by
linear extrapolation — exact for homogeneous stacked blocks:

  prefill/decode:   C(l) = rest + l * per_layer
      probes l in {2, 4};  slope = (C4 - C2) / 2
      true = C4 + (L_total - 4) * slope

  train (temporal): C(U, l) = rest + U * (c + l * per_layer)
      probes (U, l) in {(2,2), (2,4), (4,4)}
      per_layer = (C(2,4) - C(2,2)) / 4
      c         = (C(4,4) - C(2,4)) / 2 - 4 * per_layer
      rest      = C(2,2) - 2 * c - 4 * per_layer
      true      = rest + U* . (c + L* . per_layer)

Every probe keeps the production per-client batch, mesh, shardings, remat
and dtype — only the number of stacked blocks (and scan trip counts) shrink.

    PYTHONPATH=src python -m repro.launch.costprobe --records experiments/dryrun
    PYTHONPATH=src python -m repro.launch.costprobe --records experiments/dryrun_multipod --multi-pod
"""

import argparse
import dataclasses
import glob
import json
import sys

import jax

from repro.obs import now

from repro.configs import get_config, get_shape
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_dryrun, windowed_variant

PROBE_LS = (2, 4)


def _reduced_cfg(cfg, l: int):
    """Depth-l unrolled variant: decoder (and proportionally encoder) blocks."""
    enc = 0
    if cfg.enc_layers:
        enc = max(1, round(cfg.enc_layers * l / cfg.L))
    return dataclasses.replace(cfg, L=l, enc_layers=enc, unroll_layers=True)


def _cost_of(cfg, shape, mesh, *, mode, fsdp, remat):
    from repro.launch.dryrun import collective_bytes
    step, args, in_sh, out_sh, meta = build_dryrun(
        cfg, shape, mesh, mode=mode, fsdp=fsdp, remat=remat, unroll=False)
    # cfg already carries unroll_layers=True; build_dryrun(unroll=False)
    # simply does not override it.
    t0 = now()
    compiled = jax.jit(step, in_shardings=in_sh,
                       out_shardings=out_sh).lower(*args).compile()
    dt = now() - t0
    cost = compiled.cost_analysis() or {}
    coll = collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": float(coll["total"]),
            "compile_s": round(dt, 1),
            "meta": {k: v for k, v in meta.items() if k != "step"}}


def _lin2(c2, c4, l_target):
    """Linear extrapolation from depth-2/4 probes to depth l_target."""
    out = {}
    for k in ("flops", "bytes", "coll"):
        slope = (c4[k] - c2[k]) / 2.0
        out[k] = c4[k] + (l_target - 4) * slope
    return out


def probe_combo(arch: str, shape_name: str, *, multi_pod: bool,
                mode: str = "temporal", attn_window: int = 0,
                fsdp: str | None = "data", remat: bool = True,
                cfg_overrides: dict | None = None,
                verbose: bool = True) -> dict:
    """Return corrected per-device cost terms + raw probes for one combo."""
    cfg = get_config(arch)
    if attn_window:
        cfg = windowed_variant(cfg, attn_window)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    probes = {}

    if shape.kind == "train" and mode != "spatial":
        # production U/b for this mesh (mirrors specs.build_dryrun temporal)
        from repro.launch.mesh import batch_axes
        n_shards = 1
        for ax, sz in zip(mesh.axis_names, mesh.devices.shape):
            if ax in batch_axes(mesh):
                n_shards *= sz
        U_star = max(shape.global_batch // n_shards, 1)
        b_star = shape.global_batch // U_star
        samples = {}
        for (u, l) in ((2, 2), (2, 4), (4, 4)):
            sh = dataclasses.replace(shape, global_batch=u * b_star)
            c = _cost_of(_reduced_cfg(cfg, l), sh, mesh, mode=mode,
                         fsdp=fsdp, remat=remat)
            samples[f"U{u}_L{l}"] = c
            if verbose:
                print(f"  [probe] {arch} {shape_name} U={u} l={l}: "
                      f"flops {c['flops']:.3g} compile {c['compile_s']}s",
                      flush=True)
        out = {}
        for k in ("flops", "bytes", "coll"):
            per_layer = (samples["U2_L4"][k] - samples["U2_L2"][k]) / 4.0
            c_const = ((samples["U4_L4"][k] - samples["U2_L4"][k]) / 2.0
                       - 4.0 * per_layer)
            rest = samples["U2_L2"][k] - 2.0 * c_const - 4.0 * per_layer
            out[k] = rest + U_star * (c_const + cfg.L * per_layer)
        probes = {"kind": "train", "U_star": U_star, "L_star": cfg.L,
                  "samples": samples}
    else:
        # prefill/decode — and spatial-mode train, where clients are a vmap
        # batch dim (no U while-loop): depth probes alone reconstruct costs.
        cs = {}
        for l in PROBE_LS:
            c = _cost_of(_reduced_cfg(cfg, l), shape, mesh, mode=mode,
                         fsdp=fsdp, remat=remat)
            cs[l] = c
            if verbose:
                print(f"  [probe] {arch} {shape_name} l={l}: "
                      f"flops {c['flops']:.3g} compile {c['compile_s']}s",
                      flush=True)
        out = _lin2(cs[2], cs[4], cfg.L)
        probes = {"kind": shape.kind, "mode": mode, "L_star": cfg.L,
                  "samples": {f"L{l}": c for l, c in cs.items()}}

    out = {k: max(v, 0.0) for k, v in out.items()}
    return {"corrected": out, "probes": probes}


def correct_records(records_dir: str, *, multi_pod: bool,
                    only: str | None = None) -> int:
    """Rewrite each dry-run JSON with probe-corrected roofline terms."""
    from repro.launch.dryrun import HBM_BW, ICI_BW, PEAK_FLOPS
    n_fail = 0
    for fn in sorted(glob.glob(os.path.join(records_dir, "*.json"))):
        with open(fn) as f:
            rec = json.load(f)
        if "error" in rec:
            continue
        if only and only not in fn:
            continue
        arch, shape_name = rec["arch"], rec["shape"]
        attn_window = 0
        if arch.endswith("-swa4096"):
            arch, attn_window = arch[:-len("-swa4096")], 4096
        try:
            res = probe_combo(arch, shape_name, multi_pod=multi_pod,
                              mode=rec.get("mode", "temporal"),
                              attn_window=attn_window)
        except Exception as e:  # pragma: no cover
            print(f"[costprobe] FAIL {arch} x {shape_name}: {e}",
                  file=sys.stderr, flush=True)
            n_fail += 1
            continue
        corr = res["corrected"]
        rec["roofline_raw"] = rec.get("roofline_raw", rec["roofline"])
        rec["flops_per_device_raw"] = rec.get(
            "flops_per_device_raw", rec["flops_per_device"])
        rec["flops_per_device"] = corr["flops"]
        rec["bytes_per_device"] = corr["bytes"]
        rec["collective_bytes_per_device_total"] = corr["coll"]
        roof = {"compute_s": corr["flops"] / PEAK_FLOPS,
                "memory_s": corr["bytes"] / HBM_BW,
                "collective_s": corr["coll"] / ICI_BW}
        roof["dominant"] = max(("compute_s", "memory_s", "collective_s"),
                               key=lambda k: roof[k])
        rec["roofline"] = roof
        rec["cost_probes"] = res["probes"]
        with open(fn, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"[costprobe] {arch} x {shape_name} x {rec['mesh']}: "
              f"flops/dev {corr['flops']:.3g} bytes/dev {corr['bytes']:.3g} "
              f"coll/dev {corr['coll']:.3g} dominant={roof['dominant']}",
              flush=True)
    return n_fail


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--records", required=True,
                    help="directory of dry-run JSONs to correct in place")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--only", default=None,
                    help="substring filter on record filenames")
    args = ap.parse_args(argv)
    return 1 if correct_records(args.records, multi_pod=args.multi_pod,
                                only=args.only) else 0


if __name__ == "__main__":
    raise SystemExit(main())
