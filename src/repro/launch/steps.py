"""Step functions lowered onto the production mesh.

``make_train_step`` is ONE FEDERATED ROUND of ADEL-FL for a big
architecture: per-client gradients -> per-(client, layer) truncation mask
from the straggler model -> bias-corrected layer-wise aggregation (Eq. 5,
gradient form) -> server SGD update. The paper's server aggregation becomes
jax.lax/GSPMD collectives on the mesh.

Two client layouts:

* ``temporal`` (default) — clients are grad-accumulation microbatches:
  ``lax.scan`` over U, each client's batch data-parallel over the whole
  mesh; the ADEL coefficient c[u, l] is folded into the accumulation, so
  peak memory is ONE gradient pytree regardless of U. Required for the
  480B-class architectures.
* ``spatial`` — clients live on the data mesh axis (vmap over U); one
  client's full gradient per data shard. Lower latency for models whose
  gradient fits per-device; a §Perf lever.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.aggregation import (aggregate_grads, layer_coefficients,
                                    weight_by_layer as _weight_by_layer)
from repro.models import transformer as tr

PyTree = Any

__all__ = ["make_train_step", "make_prefill_step", "make_serve_step",
           "make_client_grad", "client_batch"]


def client_batch(cfg: ArchConfig, shape, U: int) -> int:
    """Per-client batch b = global_batch / U."""
    assert shape.global_batch % U == 0, (shape.global_batch, U)
    return shape.global_batch // U


def make_train_step(cfg: ArchConfig, *, U: int, mode: str = "temporal",
                    remat: bool = True, moe_aux_coef: float = 0.01):
    """Returns train_step(params, tokens, labels, mask, p, eta[, frontend]).

    tokens/labels: (U, b, S) int32; mask: (U, L_total) f32 straggler
    contribution mask; p: (L_total,) zero-contributor probabilities;
    eta: scalar f32. frontend: (U, b, n_front, D) for vlm/audio.
    Returns updated params.
    """
    has_front = cfg.frontend != "none"

    def client_loss(params, tok, lab, fr):
        return tr.loss_fn(params, cfg, tok, lab, frontend=fr,
                          moe_aux_coef=moe_aux_coef, remat=remat)

    def train_step(params, tokens, labels, mask, p, eta, frontend):
        ids = tr.layer_ids(params, cfg)
        coeffs = layer_coefficients(mask, p)       # (U, L_total)
        if mode == "spatial":
            grads = jax.vmap(jax.grad(client_loss),
                             in_axes=(None, 0, 0, 0))(
                params, tokens, labels, frontend)
            agg = aggregate_grads(grads, ids, mask, p)
        else:
            def body(acc, inp):
                tok, lab, c_row, fr = inp
                g = jax.grad(client_loss)(params, tok, lab, fr)
                gw = jax.tree.map(
                    lambda gl, idl: _weight_by_layer(
                        gl.astype(jnp.float32), idl, c_row), g, ids)
                return jax.tree.map(jnp.add, acc, gw), None

            acc0 = jax.tree.map(
                lambda w: jnp.zeros(w.shape, jnp.float32), params)
            agg, _ = jax.lax.scan(body, acc0,
                                  (tokens, labels, coeffs, frontend),
                                  unroll=bool(cfg.unroll_layers))
        new_params = jax.tree.map(
            lambda w, g: (w.astype(jnp.float32)
                          - eta * g.astype(jnp.float32)).astype(w.dtype),
            params, agg)
        return new_params

    if not has_front:
        # drop the frontend arg entirely so lowering signatures stay minimal
        def train_step_nf(params, tokens, labels, mask, p, eta):
            def client_loss_nf(params, tok, lab):
                return tr.loss_fn(params, cfg, tok, lab,
                                  moe_aux_coef=moe_aux_coef, remat=remat)

            ids = tr.layer_ids(params, cfg)
            coeffs = layer_coefficients(mask, p)
            if mode == "spatial":
                grads = jax.vmap(jax.grad(client_loss_nf),
                                 in_axes=(None, 0, 0))(params, tokens, labels)
                agg = aggregate_grads(grads, ids, mask, p)
            else:
                def body(acc, inp):
                    tok, lab, c_row = inp
                    g = jax.grad(client_loss_nf)(params, tok, lab)
                    gw = jax.tree.map(
                        lambda gl, idl: _weight_by_layer(
                            gl.astype(jnp.float32), idl, c_row), g, ids)
                    return jax.tree.map(jnp.add, acc, gw), None

                acc0 = jax.tree.map(
                    lambda w: jnp.zeros(w.shape, jnp.float32), params)
                agg, _ = jax.lax.scan(body, acc0, (tokens, labels, coeffs),
                                      unroll=bool(cfg.unroll_layers))
            return jax.tree.map(
                lambda w, g: (w.astype(jnp.float32)
                              - eta * g.astype(jnp.float32)).astype(w.dtype),
                params, agg)

        return train_step_nf
    return train_step


def make_client_grad(cfg: ArchConfig, *, remat: bool = True,
                     moe_aux_coef: float = 0.01):
    """The temporal-mode U-scan body as a standalone step, used by the
    dry-run to correct HloCostAnalysis's count-while-body-once behaviour:

        true_train_cost = module_cost + (U - 1) * client_grad_cost

    Signature: (params, tok (b,S), lab (b,S), c_row (L_tot,)[, frontend])
    -> weighted f32 gradient pytree (congruent with params).
    """
    has_front = cfg.frontend != "none"

    def _grad(params, tok, lab, fr):
        def client_loss(p):
            return tr.loss_fn(p, cfg, tok, lab, frontend=fr,
                              moe_aux_coef=moe_aux_coef, remat=remat)
        return jax.grad(client_loss)(params)

    def _weight(params, g, c_row):
        ids = tr.layer_ids(params, cfg)
        return jax.tree.map(
            lambda gl, idl: _weight_by_layer(gl.astype(jnp.float32), idl,
                                             c_row), g, ids)

    if has_front:
        def client_grad(params, tok, lab, c_row, frontend):
            return _weight(params, _grad(params, tok, lab, frontend), c_row)
        return client_grad

    def client_grad_nf(params, tok, lab, c_row):
        return _weight(params, _grad(params, tok, lab, None), c_row)
    return client_grad_nf


def make_prefill_step(cfg: ArchConfig):
    """prefill_step(params, tokens[, frontend]) -> last-position logits."""
    if cfg.frontend == "none":
        def prefill_step(params, tokens):
            return tr.prefill(params, cfg, tokens)
        return prefill_step

    def prefill_step_f(params, tokens, frontend):
        return tr.prefill(params, cfg, tokens, frontend=frontend)
    return prefill_step_f


def make_serve_step(cfg: ArchConfig, *, greedy: bool = True):
    """serve_step(params, cache, token, pos) -> (next_token, new_cache).

    ONE new token against a KV/SSM cache of the shape's seq_len.
    """

    def serve_step(params, cache, token, pos):
        logits, cache = tr.decode_step(params, cfg, cache, token, pos)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, cache

    return serve_step
