"""Abstract input specs (ShapeDtypeStruct) + shardings for every
(architecture x input shape x mesh) combination — the dry-run path.

No device memory is ever allocated here: params/caches/batches are
``jax.ShapeDtypeStruct`` stand-ins produced with ``jax.eval_shape``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, InputShape
from repro.launch.mesh import batch_axes
from repro.models import transformer as tr

PyTree = Any

__all__ = ["abstract_params", "default_clients", "build_dryrun",
           "text_len"]


def abstract_params(cfg: ArchConfig, dtype) -> PyTree:
    fn = functools.partial(tr.init_params, cfg=cfg, dtype=dtype)
    return jax.eval_shape(lambda k: fn(k), jax.ShapeDtypeStruct((2,), jnp.uint32))


def default_clients(mesh) -> int:
    """Simulated FL clients U = data-parallel group count (DESIGN §5)."""
    names = dict(zip(mesh.axis_names, mesh.devices.shape))
    U = names.get("data", 1) * names.get("pod", 1)
    return U


def text_len(cfg: ArchConfig, shape: InputShape) -> int:
    """Text tokens through the decoder. VLM: shape.seq_len covers the image
    patches + text; audio: the decoder length is shape.seq_len (frames are a
    fixed encoder-side budget)."""
    if cfg.frontend == "vision":
        return max(shape.seq_len - cfg.n_frontend_tokens, 128)
    return shape.seq_len


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _ns(mesh, spec):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec,
                        is_leaf=lambda x: isinstance(x, P))


def build_dryrun(cfg: ArchConfig, shape: InputShape, mesh, *,
                 mode: str = "temporal", U: int | None = None,
                 remat: bool = True, fsdp: str | None = "data",
                 unroll: bool = False, spatial_batch_axes=None):
    """Returns (step_fn, args, in_shardings, out_shardings, meta).

    ``unroll=True`` lowers with fully unrolled layers (the cost-analysis
    form — see ArchConfig.unroll_layers); False keeps the O(1)-HLO scan form.

    Raises ValueError for (arch, shape) combinations that are skipped by
    design (long_500k on full-attention archs; see DESIGN.md §4).
    """
    from repro.launch import steps as st

    if unroll:
        cfg = dataclasses.replace(cfg, unroll_layers=True)

    batch = batch_axes(mesh)
    bspec = batch if len(batch) > 1 else batch[0]
    n_batch_shards = 1
    for ax, sz in zip(mesh.axis_names, mesh.devices.shape):
        if ax in batch:
            n_batch_shards *= sz
    if shape.global_batch % n_batch_shards != 0:
        bspec = None               # e.g. long_500k B=1: replicate the batch dim
    L_tot = cfg.n_blocks_total
    fsdp_ax = fsdp
    pspec = tr.param_specs(abstract_params(cfg, jnp.float32), cfg,
                           fsdp=fsdp_ax, tp="model")

    if shape.kind == "train":
        if U is None:
            if mode == "spatial":
                U = n_batch_shards          # clients live on the batch axes
            else:
                # temporal: per-client batch exactly fills the batch shards
                U = max(shape.global_batch // n_batch_shards, 1)
        b = st.client_batch(cfg, shape, U)
        if mode != "spatial" and b % n_batch_shards != 0:
            raise ValueError(f"client batch {b} not divisible by "
                             f"{n_batch_shards} batch shards")
        S = text_len(cfg, shape)
        params = abstract_params(cfg, jnp.float32)
        tok = _sds((U, b, S), jnp.int32)
        lab = _sds((U, b, S), jnp.int32)
        mask = _sds((U, L_tot), jnp.float32)
        p = _sds((L_tot,), jnp.float32)
        eta = _sds((), jnp.float32)
        if mode == "spatial":
            dspec = P(bspec, None, None)
        else:
            dspec = P(None, bspec, None)
        args = [params, tok, lab, mask, p, eta]
        shard = [pspec, dspec, dspec, P(None, None), P(None), P()]
        step = st.make_train_step(cfg, U=U, mode=mode, remat=remat)
        if cfg.frontend != "none":
            nf = cfg.n_frontend_tokens
            args.append(_sds((U, b, nf, cfg.d_model), jnp.bfloat16))
            shard.append(P(None, bspec, None, None) if mode != "spatial"
                         else P(bspec, None, None, None))
        out_shard = pspec
        meta = {"step": "train_step", "U": U, "client_batch": b, "seq": S}

    elif shape.kind == "prefill":
        B = shape.global_batch
        S = text_len(cfg, shape)
        params = abstract_params(cfg, jnp.bfloat16)
        step = st.make_prefill_step(cfg)
        args = [params, _sds((B, S), jnp.int32)]
        shard = [pspec, P(bspec, None)]
        if cfg.frontend != "none":
            args.append(_sds((B, cfg.n_frontend_tokens, cfg.d_model),
                             jnp.bfloat16))
            shard.append(P(bspec, None, None))
        out_shard = P(bspec, "model")
        meta = {"step": "prefill_step", "B": B, "seq": S}

    else:  # decode
        if not cfg.sub_quadratic and shape.seq_len > 262_144:
            raise ValueError(
                f"{cfg.name} is full-attention; long_500k is skipped per "
                "DESIGN.md §4 (use --attn-window for the SWA variant)")
        B = shape.global_batch
        S = shape.seq_len
        params = abstract_params(cfg, jnp.bfloat16)
        cache = jax.eval_shape(
            lambda: tr.init_cache(cfg, B, S, dtype=jnp.bfloat16))
        if cfg.enc_layers:
            enc = _sds((B, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16)
            aparams = params
            cross = jax.eval_shape(
                lambda pp, ee: tr.build_cross_cache(pp, cfg, ee),
                aparams, enc)
            cache = cache._replace(cross=cross)
        cspec = tr.cache_specs(cache, cfg, batch=bspec, tp="model")
        step = st.make_serve_step(cfg)
        args = [params, cache, _sds((B,), jnp.int32), _sds((), jnp.int32)]
        shard = [pspec, cspec, P(bspec), P()]
        out_shard = (P(bspec), cspec)
        meta = {"step": "serve_step", "B": B, "cache_seq": S}

    in_sh = tuple(_ns(mesh, s) for s in shard)
    out_sh = _ns(mesh, out_shard)
    return step, tuple(args), in_sh, out_sh, meta


def build_client_probe(cfg: ArchConfig, shape: InputShape, mesh, *,
                       U: int, b: int, remat: bool = True,
                       fsdp: str | None = "data", unroll: bool = True):
    """Standalone temporal-mode scan-body (one client's weighted gradient)
    for the dry-run cost correction. Same shardings as the train module."""
    from repro.launch import steps as st

    if unroll:
        cfg = dataclasses.replace(cfg, unroll_layers=True)
    batch = batch_axes(mesh)
    bspec = batch if len(batch) > 1 else batch[0]
    L_tot = cfg.n_blocks_total
    pspec = tr.param_specs(abstract_params(cfg, jnp.float32), cfg,
                           fsdp=fsdp, tp="model")
    S = text_len(cfg, shape)
    params = abstract_params(cfg, jnp.float32)
    args = [params, _sds((b, S), jnp.int32), _sds((b, S), jnp.int32),
            _sds((L_tot,), jnp.float32)]
    shard = [pspec, P(bspec, None), P(bspec, None), P(None)]
    if cfg.frontend != "none":
        args.append(_sds((b, cfg.n_frontend_tokens, cfg.d_model),
                         jnp.bfloat16))
        shard.append(P(bspec, None, None))
    step = st.make_client_grad(cfg, remat=remat)
    in_sh = tuple(_ns(mesh, s) for s in shard)
    out_sh = _ns(mesh, pspec)
    return step, tuple(args), in_sh, out_sh


def windowed_variant(cfg: ArchConfig, window: int = 4096) -> ArchConfig:
    """Beyond-paper sliding-window serve variant for dense archs (enables
    long_500k dry-runs; recorded separately in EXPERIMENTS.md)."""
    return dataclasses.replace(cfg, window=window,
                               name=f"{cfg.name}-swa{window}")
