"""End-to-end federated LM training driver (ADEL-FL on an assigned arch).

A thin front-end over the unified round runtime: the arch config becomes a
:func:`repro.fl.tasks.lm_task` (transformer ``ModelAPI`` + synthetic token
streams + token-loss eval), and the round loop is
:class:`repro.fl.runtime.RoundRuntime` — the SAME loop that serves the
image and fleet workloads — so the paper's full pipeline (Problem-2
schedule -> per-round straggler draws (B1-B3) -> deadline-truncated
layer-wise aggregation (Eq. 5) -> SGD) plus online re-planning, every
execution backend (``dense`` / ``chunked`` / ``shard_map`` / ``temporal``
— the grad-accumulation client layout required for the big archs — /
``buffered``, the semi-async delayed-gradient backend), and HeteroFL
width scaling all work on LM configs with no LM-specific loop code. The
execution surface is one :class:`repro.fl.spec.ExecSpec` (``exec=`` /
the shared ``--backend/--compression/--lam/...`` CLI group).
Checkpointing rides the runtime's ``on_round`` hook.

On the CPU container use --reduced (default); the full configs are
exercised via dryrun.py.

    PYTHONPATH=src python -m repro.launch.train --arch hymba-1.5b \
        --method adel --rounds 60 --tmax 240 --backend temporal
"""
from __future__ import annotations

import argparse
import contextlib
import json
import math

import jax
import numpy as np

from repro import obs
from repro.checkpoint import save_checkpoint
from repro.configs import get_config
from repro.core.baselines import make_policy
from repro.core.replan import TRIGGERS, ReplanConfig
from repro.core.scheduler import solve
from repro.core.types import AnalysisConfig
from repro.fl.runtime import History, RoundRuntime, probe_s_max
from repro.fl.spec import ExecSpec
from repro.fl.tasks import lm_task
from repro.fleet.population import PopulationSpec


def run_training(arch: str, *, method: str = "adel", rounds: int = 40,
                 tmax: float = 160.0, U: int = 8, seq: int = 64,
                 n_seq: int = 96, eta0: float = 0.5, seed: int = 0,
                 reduced: bool = True, solver: str = "adam",
                 solver_steps: int | None = None,
                 exec: ExecSpec | None = None,
                 backend: str | None = None, chunk_size: int | None = None,
                 mesh=None, replan=None, local_iters: int | None = None,
                 donate: bool | None = None,
                 compression=None, agg_impl: str | None = None,
                 population=None,
                 s_max_cap: int = 32, eval_every: int | None = None,
                 ckpt: str | None = None, ckpt_every: int | None = None,
                 verbose: bool = True, tracer=None) -> tuple[object, History]:
    """Federated LM training on ``RoundRuntime``; returns ``(params,
    History)`` — ``History.accuracy`` is next-token accuracy and
    ``History.train_loss`` the token CE over a fixed in-pool eval head
    (perplexity = exp; see :func:`repro.fl.tasks.lm_task` for why the
    synthetic stream has no meaningful held-out split).

    HOW rounds execute is one :class:`repro.fl.spec.ExecSpec` (``exec=``):
    backend choice (``dense`` default; ``temporal`` is the big-arch
    grad-accumulation layout, ``buffered`` the semi-async delayed-gradient
    backend), ``chunk_size`` / ``mesh``, ``local_iters``, donation,
    ``compression`` / ``agg_impl``, and the staleness knobs. The
    individual kwargs remain as deprecated aliases; both forms funnel
    through :meth:`ExecSpec.resolve` (bit-identical either way). The
    spec's ``compression`` is priced into the Problem-2 plan before
    solving.

    ``replan`` selects the online re-planning trigger (None | "never" |
    "every-k" | "drift" | :class:`repro.core.replan.ReplanConfig`),
    ``ckpt`` a checkpoint path saved every ``ckpt_every`` rounds (default
    R/4) through the runtime's ``on_round`` hook, ``tracer`` a
    :class:`repro.obs.Tracer` for structured telemetry (phase spans +
    clock-model ledger in ``History.telemetry``).

    ``population`` (None by default) switches WHO the LM trains against:
    a :class:`repro.fleet.population.PopulationSpec` / source string /
    :class:`Population` routes the run through
    :func:`repro.fleet.engine.run_fleet` — per-round availability and
    cohort sampling over a simulated device fleet (lazy parametric
    populations scale to millions of devices) instead of the static
    ``U``-client pool. The cohort size stays ``U``; ``ckpt`` is not
    supported on the fleet path.
    """
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()

    spec = ExecSpec.resolve(exec, backend=backend, chunk_size=chunk_size,
                            mesh=mesh, local_iters=local_iters,
                            donate=donate, compression=compression,
                            agg_impl=agg_impl)
    if population is not None:
        if ckpt:
            raise ValueError("ckpt= is not supported on the fleet "
                             "(population=) path")
        from repro.fl.tasks import (lm_eval_metrics, lm_fleet_data,
                                    make_lm_model)
        from repro.fleet.engine import run_fleet
        from repro.fleet.population import make_population
        pop = make_population(population)
        model = make_lm_model(cfg)
        # virtual sharding: device id mod shards, so million-device
        # populations never materialize per-device token arrays
        data = lm_fleet_data(cfg, min(pop.size, 1024), seq=seq,
                             rows_per_device=max(n_seq // U, 4), seed=seed)
        return run_fleet(
            model, pop, data=data, method=method, rounds=rounds,
            T_max=tmax, cohort_size=U, exec=spec, eta0=eta0,
            solver=solver, solver_steps=solver_steps or 600,
            eval_every=eval_every or max(rounds // 20, 1), seed=seed,
            verbose=verbose, replan=replan, eval_metrics=lm_eval_metrics,
            tracer=tracer)
    task = lm_task(cfg, U=U, seq=seq, n_seq=n_seq, seed=seed)
    acfg = AnalysisConfig.default(U=U, L=task.model.L, R=rounds, T_max=tmax,
                                  eta0=eta0, seed=seed)
    comp = spec.compression
    if comp.mode != "none":
        # price the compressed wire into the Problem-2 plan: B_u shrinks by
        # the wire ratio, so the solved schedule re-spends the freed
        # deadline budget on larger batches (Schedule.batch_sizes / B_eff)
        import dataclasses as _dc
        n_params = sum(int(np.prod(l.shape)) for l in
                       jax.tree.leaves(jax.eval_shape(
                           task.model.init,
                           jax.ShapeDtypeStruct((2,), np.uint32))))
        acfg = _dc.replace(acfg, comm_scale=comp.wire_scale(),
                           bytes_full=4.0 * n_params)
    schedule = None
    if method == "adel":
        kw = {"steps": solver_steps} if (solver == "adam"
                                         and solver_steps) else {}
        schedule = solve(acfg, solver, **kw)
    policy = make_policy(method, acfg, schedule=schedule)
    # the minibatch pad width prices EVERY client's round compute at
    # O(s_max) sequences, so cap it: larger planned batches are clipped by
    # the sampler (only the straggler clock keeps the full B3 batch) —
    # raise s_max_cap on real accelerators
    s_max = max(min(probe_s_max(policy, rounds), s_max_cap,
                    4 * task.n_per_client), 2)

    runtime = RoundRuntime(task.model, policy, exec=spec, tracer=tracer)

    on_round = None
    if ckpt:
        every = ckpt_every or max(rounds // 4, 1)

        def on_round(t, params, hist):
            if (t + 1) % every == 0 or t == rounds - 1:
                save_checkpoint(ckpt, params, step=t + 1,
                                meta={"arch": cfg.name, "method": method,
                                      "backend": spec.backend})

    params, hist = runtime.run(
        task.source(), rounds=rounds, T_max=tmax, eta=acfg.eta, s_max=s_max,
        key=jax.random.PRNGKey(seed), eval_fn=task.eval_fn(),
        eval_every=eval_every or max(rounds // 20, 1), verbose=verbose,
        method=method, replan=replan, on_round=on_round)
    if ckpt and (not hist.rounds or hist.rounds[-1] < rounds):
        # budget exhausted before the last planned round: persist the final
        # params the periodic hook may have missed
        save_checkpoint(ckpt, params, step=hist.rounds[-1] if hist.rounds
                        else 0, meta={"arch": cfg.name, "method": method,
                                      "backend": spec.backend})
    return params, hist


@contextlib.contextmanager
def _profile(trace_dir: str | None):
    """Opt-in ``jax.profiler`` device trace around the training run.

    Best-effort: some CPU-only / stripped builds lack a working profiler
    backend, and a missing trace must never kill a training run — failures
    downgrade to a warning.
    """
    if not trace_dir:
        yield
        return
    started = False
    try:
        jax.profiler.start_trace(trace_dir)
        started = True
    except Exception as e:  # pragma: no cover - backend-dependent
        print(f"[train] jax.profiler unavailable ({e}); continuing "
              f"without a device trace")
    try:
        yield
    finally:
        if started:
            try:
                jax.profiler.stop_trace()
                print(f"[train] device trace -> {trace_dir}")
            except Exception as e:  # pragma: no cover - backend-dependent
                print(f"[train] jax.profiler.stop_trace failed ({e})")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="hymba-1.5b")
    ap.add_argument("--method", default="adel",
                    choices=["adel", "salf", "drop", "wait", "heterofl"])
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--tmax", type=float, default=160.0)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--eta0", type=float, default=0.5)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="reduced arch for the CPU container (default)")
    ap.add_argument("--full", dest="reduced", action="store_false",
                    help="use the full (non-reduced) config — TPU only")
    ap.add_argument("--replan", default=None, choices=list(TRIGGERS),
                    help="online re-planning trigger (repro.core.replan)")
    ap.add_argument("--replan-every", type=int, default=None,
                    help="every-k re-plan period")
    # the shared execution-spec flag block (--backend / --chunk-size /
    # --no-donate / --compression / --agg-impl / --lam / ...) — one
    # surface with repro.fleet.scenarios, derived from repro.fl.spec
    ExecSpec.add_cli_args(ap)
    # ... and the shared population flag block (--population / --fleet-size
    # / --availability / --regions): any of these set routes the run over a
    # simulated device fleet via repro.fleet.engine.run_fleet
    PopulationSpec.add_cli_args(ap)
    ap.add_argument("--solver", default="adam",
                    choices=["adam", "trust-constr"])
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--out", default=None)
    ap.add_argument("--events", default=None, metavar="PATH",
                    help="write the structured telemetry stream (phase "
                         "spans, clock-model ledger) to this JSONL file; "
                         "render with python -m repro.obs.timeline")
    ap.add_argument("--profile-dir", default=None, metavar="DIR",
                    help="capture a jax.profiler device trace of the whole "
                         "run into DIR (view with TensorBoard / Perfetto); "
                         "opt-in — profiling is skipped with a warning if "
                         "the profiler backend is unavailable")
    args = ap.parse_args(argv)
    replan = args.replan
    if replan is not None and args.replan_every is not None:
        replan = ReplanConfig(trigger=replan, every=args.replan_every)
    spec = ExecSpec.from_cli(args)
    pop_flags = (args.population, args.fleet_size, args.availability,
                 args.regions)
    pspec = (PopulationSpec.from_cli(args)
             if any(v is not None for v in pop_flags) else None)
    tracer = obs.make_tracer(args.events)
    t0 = obs.now()
    with _profile(args.profile_dir):
        _, hist = run_training(args.arch, method=args.method,
                               rounds=args.rounds,
                               tmax=args.tmax, U=args.clients, eta0=args.eta0,
                               seq=args.seq, seed=args.seed,
                               reduced=args.reduced, solver=args.solver,
                               exec=spec, replan=replan, population=pspec,
                               ckpt=args.ckpt, tracer=tracer)
    tracer.close()
    loss = hist.train_loss[-1]
    print(f"[train] done in {obs.now() - t0:.1f}s wall; "
          f"final token loss {loss:.4f} (ppl {math.exp(min(loss, 30)):.1f}, "
          f"token acc {hist.accuracy[-1]:.4f})")
    if args.events:
        print(f"[train] telemetry -> {args.events} "
              f"(render: python -m repro.obs.timeline {args.events})")
    if args.out:
        with open(args.out, "w") as f:
            json.dump({**hist.as_dict(), "arch": args.arch,
                       "backend": spec.backend,
                       "exec": spec.as_dict()}, f, indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
