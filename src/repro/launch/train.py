"""End-to-end federated training driver (ADEL-FL on an assigned arch).

Runs a REAL federated optimization of a (reduced, unless --full) architecture
on synthetic LM token streams, with the paper's full pipeline: Problem-2
schedule -> per-round straggler draws (B1-B3) -> deadline-truncated
layer-wise aggregation (Eq. 5) -> SGD. On the CPU container use --reduced
(default); the full configs are exercised via dryrun.py.

    PYTHONPATH=src python -m repro.launch.train --arch hymba-1.5b \
        --method adel --rounds 60 --tmax 240
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import get_config
from repro.core.baselines import make_policy
from repro.core.scheduler import solve
from repro.core.types import AnalysisConfig
from repro.data.synthetic import make_lm_dataset
from repro.launch.steps import make_train_step
from repro.models import transformer as tr


def run_training(arch: str, *, method: str = "adel", rounds: int = 40,
                 tmax: float = 160.0, U: int = 8, client_batch: int = 4,
                 seq: int = 64, eta0: float = 0.5, seed: int = 0,
                 reduced: bool = True, solver: str = "adam",
                 ckpt: str | None = None, verbose: bool = True) -> dict:
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    L_tot = cfg.n_blocks_total

    acfg = AnalysisConfig.default(U=U, L=L_tot, R=rounds, T_max=tmax,
                                  eta0=eta0, seed=seed)
    schedule = solve(acfg, solver) if method == "adel" else None
    policy = make_policy(method, acfg, schedule=schedule)

    key = jax.random.PRNGKey(seed)
    key, k_init = jax.random.split(key)
    params = tr.init_params(k_init, cfg)

    # synthetic token stream, contiguous shards per client (non-IID by stream
    # position), each client's pool reshaped to (n_seq, seq+1)
    toks = make_lm_dataset(vocab=min(cfg.vocab, 2048),
                           n_tokens=U * 96 * (seq + 1), seed=seed)
    pool = toks.reshape(U, -1, seq + 1)
    n_seq = pool.shape[1]

    step = jax.jit(make_train_step(cfg, U=U, mode="spatial", remat=False))
    eval_tok = jnp.asarray(pool[:, :2, :-1].reshape(-1, seq))
    eval_lab = jnp.asarray(pool[:, :2, 1:].reshape(-1, seq))
    eval_loss = jax.jit(lambda p: tr.loss_fn(p, cfg, eval_tok, eval_lab))

    hist = {"round": [], "time": [], "loss": [], "deadline": [],
            "method": method, "arch": cfg.name}
    elapsed = 0.0
    eta = acfg.eta
    for t in range(rounds):
        key, k_round, k_batch = jax.random.split(key, 3)
        plan = policy.round(k_round, t)
        if elapsed + plan.elapsed > tmax * (1 + 1e-6):
            break
        # per-client minibatch of fixed CLIENT_BATCH sequences (batch size
        # S_t^u modulates the straggler clock; token count is fixed so the
        # jit signature is stable)
        idx = np.asarray(jax.random.randint(
            k_batch, (U, client_batch), 0, n_seq))
        xb = np.stack([pool[u, idx[u]] for u in range(U)])      # (U,b,seq+1)
        tok = jnp.asarray(xb[:, :, :-1])
        lab = jnp.asarray(xb[:, :, 1:])
        params = step(params, tok, lab, plan.mask, plan.p,
                      jnp.float32(eta[t]))
        elapsed += plan.elapsed
        if t % max(rounds // 20, 1) == 0 or t == rounds - 1:
            lo = float(eval_loss(params))
            hist["round"].append(t + 1)
            hist["time"].append(elapsed)
            hist["loss"].append(lo)
            hist["deadline"].append(float(plan.elapsed))
            if verbose:
                print(f"[{method}] round {t + 1:3d}  clock {elapsed:8.2f}  "
                      f"deadline {plan.elapsed:7.3f}  loss {lo:.4f}")
    if ckpt:
        save_checkpoint(ckpt, params, step=len(hist["round"]),
                        meta={"arch": cfg.name, "method": method})
    return hist


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="hymba-1.5b")
    ap.add_argument("--method", default="adel",
                    choices=["adel", "salf", "drop", "wait", "heterofl"])
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--tmax", type=float, default=160.0)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--eta0", type=float, default=0.5)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--full", action="store_true",
                    help="use the full (non-reduced) config — TPU only")
    ap.add_argument("--solver", default="adam",
                    choices=["adam", "trust-constr"])
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    t0 = time.time()
    hist = run_training(args.arch, method=args.method, rounds=args.rounds,
                        tmax=args.tmax, U=args.clients, eta0=args.eta0,
                        seq=args.seq, seed=args.seed,
                        reduced=not args.full, solver=args.solver,
                        ckpt=args.ckpt)
    print(f"[train] done in {time.time() - t0:.1f}s wall; "
          f"final loss {hist['loss'][-1]:.4f}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(hist, f, indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
