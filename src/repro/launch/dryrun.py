import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: prove every (architecture x input-shape x mesh)
combination lowers + compiles on the production mesh, and extract the
roofline terms from the compiled artifact.

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k --multi-pod
    PYTHONPATH=src python -m repro.launch.dryrun --all --out experiments/dryrun

The two XLA_FLAGS lines above MUST precede any jax import (device count is
locked at first init). Smoke tests / benches never import this module, so
they see the single real CPU device.
"""

import argparse
import json
import re
import sys

import jax

from repro.obs import now

from repro.configs import ARCHS, INPUT_SHAPES, get_config, get_shape
from repro.launch.mesh import make_production_mesh, mesh_chips
from repro.launch.specs import build_client_probe, build_dryrun, windowed_variant

# TPU v5e hardware constants (DESIGN.md §6)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link per chip

_COLL_RE = re.compile(
    r"ROOT\s+\S+\s*=\s*|(\S+)\s*=\s*((?:\((?:[^()]|\([^()]*\))*\))|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|s32|u64|u32|s16|u16|s8|u8|pred)"
                       r"\[([0-9,]*)\]")

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
                "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-operand bytes of every collective op in (SPMD, per-device)
    HLO. Returns {op_kind: bytes, ..., 'total': bytes, 'count': n}."""
    out: dict = {}
    count = 0
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(
            r"(?:ROOT\s+)?\S+\s*=\s*((?:\((?:[^()]|\([^()]*\))*\))|\S+?)\s+"
            r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
            r"collective-permute)(?:-start)?\(", line)
        if not m:
            continue
        ty, kind = m.group(1), m.group(2)
        by = _shape_bytes(ty)
        out[kind] = out.get(kind, 0) + by
        count += 1
    out["total"] = sum(v for k, v in out.items() if k != "count")
    out["count"] = count
    return out


def _compile_and_cost(step, args, in_sh, out_sh):
    """jit -> lower -> compile; return (compiled, flops, bytes, coll, times)."""
    t0 = now()
    jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
    lowered = jitted.lower(*args)
    t_lower = now() - t0
    t0 = now()
    compiled = lowered.compile()
    t_compile = now() - t0
    cost = compiled.cost_analysis() or {}
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(compiled.as_text())
    return compiled, flops, bytes_acc, coll, (t_lower, t_compile)


def run_combo(arch: str, shape_name: str, *, multi_pod: bool = False,
              mode: str = "temporal", attn_window: int = 0,
              fsdp: str | None = "data", remat: bool = True,
              unroll: bool = False, verbose: bool = True) -> dict:
    """Lower + compile one (arch x shape x mesh) combination.

    ``unroll=True`` lowers the cost-analysis form: layers fully
    unrolled so HloCostAnalysis sees every block (it counts while-loop bodies
    once, undercounting the scan form by ~L). For train steps the remaining
    U-client scan is corrected with a standalone scan-body probe:
    true = module + (U-1) * client_body. ``unroll=False`` records the
    production scan form (HLO size O(1) in depth) without correction.
    """
    cfg = get_config(arch)
    if attn_window:
        cfg = windowed_variant(cfg, attn_window)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh_chips(mesh)
    step, args, in_sh, out_sh, meta = build_dryrun(
        cfg, shape, mesh, mode=mode, fsdp=fsdp, remat=remat, unroll=unroll)
    compiled, flops, bytes_acc, coll, (t_lower, t_compile) = \
        _compile_and_cost(step, args, in_sh, out_sh)
    try:
        mem = compiled.memory_analysis()
        mem_d = {k: int(getattr(mem, k)) for k in
                 ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes")
                 if hasattr(mem, k)}
    except Exception as e:  # pragma: no cover
        mem_d = {"error": str(e)}

    probe_d = None
    if meta["step"] == "train_step" and mode == "temporal" and unroll:
        # correct the U-client scan (counted once by HloCostAnalysis)
        U, b = meta["U"], meta["client_batch"]
        pstep, pargs, pin, pout = build_client_probe(
            cfg, shape, mesh, U=U, b=b, remat=remat, fsdp=fsdp, unroll=True)
        _, pf, pb, pc, (_, pt) = _compile_and_cost(pstep, pargs, pin, pout)
        probe_d = {"flops": pf, "bytes": pb, "coll": pc["total"],
                   "compile_s": round(pt, 1)}
        flops += (U - 1) * pf
        bytes_acc += (U - 1) * pb
        coll = dict(coll)
        coll["total"] += (U - 1) * pc["total"]

    record = {
        "arch": cfg.name, "shape": shape.name, "mesh": "2x16x16" if multi_pod
        else "16x16", "chips": chips, "mode": mode, **meta,
        "unroll": unroll,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        # cost_analysis / HLO text are per-device after SPMD partitioning
        "flops_per_device": flops, "bytes_per_device": bytes_acc,
        "collective_bytes_per_device": coll,
        "client_probe": probe_d,
        "memory": mem_d,
        "roofline": {
            "compute_s": flops / PEAK_FLOPS,
            "memory_s": bytes_acc / HBM_BW,
            "collective_s": coll["total"] / ICI_BW,
        },
    }
    r = record["roofline"]
    record["roofline"]["dominant"] = max(
        ("compute_s", "memory_s", "collective_s"), key=lambda k: r[k])
    if verbose:
        print(f"[dryrun] {cfg.name} x {shape.name} x {record['mesh']} "
              f"({meta['step']}, mode={mode}): compile {t_compile:.1f}s  "
              f"flops/dev {flops:.3g}  bytes/dev {bytes_acc:.3g}  "
              f"coll/dev {coll['total']:.3g}  dominant={r['dominant']}")
    return record


def iter_combos(include_swa: bool = False):
    for arch, cfg in ARCHS.items():
        for shape_name, shape in INPUT_SHAPES.items():
            if (shape.kind == "decode" and shape.seq_len > 262_144
                    and not cfg.sub_quadratic):
                if include_swa:
                    yield arch, shape_name, {"attn_window": 4096}
                continue
            yield arch, shape_name, {}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mode", default="temporal",
                    choices=["temporal", "spatial"])
    ap.add_argument("--attn-window", type=int, default=0)
    ap.add_argument("--fsdp", default="data")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--unroll", action="store_true",
                    help="lower with fully unrolled layers (exact cost "
                         "analysis; SLOW for train steps — prefer "
                         "repro.launch.costprobe for corrected costs)")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) for this mesh")
    ap.add_argument("--out", default=None, help="JSON output path or dir")
    args = ap.parse_args(argv)

    fsdp = None if args.fsdp in ("none", "") else args.fsdp
    records = []
    if args.all:
        for arch, shape_name, kw in iter_combos():
            try:
                rec = run_combo(arch, shape_name, multi_pod=args.multi_pod,
                                mode=args.mode, fsdp=fsdp,
                                remat=not args.no_remat,
                                unroll=args.unroll, **kw)
            except Exception as e:
                rec = {"arch": arch, "shape": shape_name,
                       "mesh": "2x16x16" if args.multi_pod else "16x16",
                       "error": f"{type(e).__name__}: {e}"}
                print(f"[dryrun] FAIL {arch} x {shape_name}: {e}",
                      file=sys.stderr)
            records.append(rec)
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        records.append(run_combo(
            args.arch, args.shape, multi_pod=args.multi_pod, mode=args.mode,
            attn_window=args.attn_window, fsdp=fsdp,
            remat=not args.no_remat, unroll=args.unroll))

    if args.out:
        out = args.out
        if os.path.isdir(out) or not out.endswith(".json"):
            os.makedirs(out, exist_ok=True)
            for rec in records:
                fn = (f"{rec['arch']}__{rec['shape']}__"
                      f"{rec['mesh'].replace('x', '_')}.json")
                with open(os.path.join(out, fn), "w") as f:
                    json.dump(rec, f, indent=1)
        else:
            with open(out, "w") as f:
                json.dump(records, f, indent=1)
    n_fail = sum(1 for r in records if "error" in r)
    print(f"[dryrun] {len(records) - n_fail}/{len(records)} combos OK")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
