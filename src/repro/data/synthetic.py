"""Synthetic datasets (the container is offline — see DESIGN.md §6).

* ``make_image_dataset`` — procedural class-template image classification
  data with MNIST-like (28x28x1) or CIFAR-like (32x32x3) shapes. Each class
  is a smooth random template; samples are shifted, scaled and noised copies.
  Linear models reach moderate accuracy, convnets high accuracy — enough
  signal to reproduce the paper's *ordering* claims under a time budget.
* ``make_lm_dataset`` — deterministic synthetic token streams with local
  n-gram structure for the LM-architecture federated examples.
"""
from __future__ import annotations

import numpy as np

__all__ = ["make_image_dataset", "make_lm_dataset"]


def _smooth_noise(rng, shape, passes: int = 3):
    x = rng.standard_normal(shape).astype(np.float32)
    for _ in range(passes):
        for ax in range(len(shape) - 1):  # skip channel axis
            x = 0.5 * x + 0.25 * (np.roll(x, 1, ax) + np.roll(x, -1, ax))
    return x


def make_image_dataset(kind: str = "mnist", n_train: int = 6000,
                       n_test: int = 1000, n_classes: int = 10,
                       seed: int = 0, noise_std: float = 1.5,
                       templates_per_class: int = 3):
    """Returns (x_train, y_train, x_test, y_test); centered floats, NHWC.

    ``noise_std`` controls task difficulty (templates are unit-std).
    ``templates_per_class`` > 1 makes each class a UNION of clusters, so
    narrow models (e.g. HeteroFL width-reduced submodels) lack the capacity
    to separate all of them — matching the qualitative behaviour of the
    paper's real-image experiments.
    """
    if kind == "mnist":
        h, w, c = 28, 28, 1
    elif kind == "cifar":
        h, w, c = 32, 32, 3
    else:
        raise ValueError(kind)
    rng = np.random.default_rng(seed)
    K = templates_per_class
    templates = np.stack([
        _smooth_noise(rng, (h, w, c)) for _ in range(n_classes * K)])
    templates = templates / np.abs(templates).std(axis=(1, 2, 3), keepdims=True)

    def sample(n, rg):
        y = rg.integers(0, n_classes, n)
        sub = rg.integers(0, K, n)
        shift_y = rg.integers(-2, 3, n)
        shift_x = rg.integers(-2, 3, n)
        gain = rg.uniform(0.8, 1.2, n).astype(np.float32)
        x = templates[y * K + sub]
        for i in range(n):
            x[i] = np.roll(np.roll(x[i], shift_y[i], 0), shift_x[i], 1)
        x = gain[:, None, None, None] * x
        x = x + noise_std * rg.standard_normal(x.shape).astype(np.float32)
        x = x - x.mean()
        x = x / max(x.std(), 1e-6)   # zero-mean, unit-std (as real pipelines)
        return x.astype(np.float32), y.astype(np.int32)

    x_tr, y_tr = sample(n_train, np.random.default_rng(seed + 1))
    x_te, y_te = sample(n_test, np.random.default_rng(seed + 2))
    return x_tr, y_tr, x_te, y_te


def make_lm_dataset(vocab: int = 1024, n_tokens: int = 262144, seed: int = 0,
                    order: int = 2):
    """Markov token stream: learnable short-range structure."""
    rng = np.random.default_rng(seed)
    # sparse transition preference: each context prefers a few tokens
    n_ctx = 4096
    pref = rng.integers(0, vocab, size=(n_ctx, 4))
    toks = np.empty(n_tokens, np.int32)
    toks[:order] = rng.integers(0, vocab, order)
    state = int(toks[:order].sum()) % n_ctx
    for i in range(order, n_tokens):
        if rng.random() < 0.8:
            toks[i] = pref[state][rng.integers(0, 4)]
        else:
            toks[i] = rng.integers(0, vocab)
        state = (state * 31 + int(toks[i])) % n_ctx
    return toks
