"""``ExecSpec`` — ONE execution spec for every federated entry point.

Before this module, the tuple ``backend / chunk_size / mesh / local_iters /
l2 / donate / compression / agg_impl`` was copy-pasted into every front-end
signature (``make_backend``, ``run_federated``, ``run_fleet``,
``run_training``) and every CLI grew its own ``--backend/--compression/...``
flag block. :class:`ExecSpec` bundles the whole tuple — plus the buffered
(semi-async) backend's staleness knobs ``lam`` / ``max_age`` /
``buffer_cap`` — into one frozen dataclass that is:

* accepted as ``exec=`` by every entry point, with the old kwargs kept as
  deprecated aliases resolved through the single parsing path
  :meth:`ExecSpec.resolve` (bit-identical trajectories either way);
* the single source of the CLI surface: :meth:`ExecSpec.add_cli_args`
  installs one shared argparse group and :meth:`ExecSpec.from_cli` reads it
  back, so ``python -m repro.fleet.scenarios`` and ``repro.launch.train``
  share one flag block;
* where knob validation lives: :meth:`ExecSpec.resolve` warns on knob
  combinations the selected backend silently ignores (``chunk_size`` on a
  non-chunked backend, ``mesh`` off shard_map, staleness knobs off the
  buffered backend, ``agg_impl="pallas"`` under shard_map) — or raises,
  under ``strict=True`` / ``REPRO_EXEC_STRICT=1``.

The canonical backend/agg-impl name tuples live here (re-exported by
:mod:`repro.fl.backends`, which imports this module) so the spec never
needs a circular import to validate itself.
"""
from __future__ import annotations

import dataclasses
import os
import warnings
from typing import Any, Optional

from repro.core.compression import (MODES as COMPRESSION_MODES,
                                    CompressionConfig, make_compression)

__all__ = ["BACKENDS", "AGG_IMPLS", "PIPELINES", "ExecSpec"]

# dense: one vmap over the cohort; chunked: sequential software psum;
# shard_map: a real client mesh axis; temporal: grad-accumulation scan;
# buffered: dense + a staleness-weighted delayed-gradient carry buffer;
# hierarchical: per-edge-region partial aggregates + one global Eq. 5 fold
BACKENDS = ("dense", "chunked", "shard_map", "temporal", "buffered",
            "hierarchical")

AGG_IMPLS = ("jnp", "pallas")

# serial: the classic loop (plan round t, run round t, repeat);
# prefetch: one-round-lookahead driver — round t+1's host phases run on a
# worker thread while round t's device step is in flight (see
# repro.fl.runtime for the execution timeline; trajectories bit-identical)
PIPELINES = ("serial", "prefetch")

# legacy-kwarg aliases `resolve` understands, in ExecSpec field order
_FIELDS = ("backend", "chunk_size", "mesh", "local_iters", "l2", "donate",
           "compression", "agg_impl", "lam", "max_age", "buffer_cap",
           "regions", "pipeline")


@dataclasses.dataclass(frozen=True)
class ExecSpec:
    """How federated rounds execute: backend + its knobs, in one value.

    ``backend`` selects the :mod:`repro.fl.backends` execution backend;
    ``chunk_size`` / ``mesh`` configure the chunked / shard_map backends;
    ``local_iters`` / ``l2`` shape the client-side local update;
    ``donate`` controls params-buffer donation in the round steps;
    ``compression`` is the client->server wire format
    (:mod:`repro.core.compression` spec — normalized to a
    :class:`CompressionConfig` on construction); ``agg_impl`` picks the
    Eq. 5 fold implementation (``"jnp"`` or the fused Pallas kernels).

    The staleness knobs drive the ``buffered`` semi-async backend: a
    straggler's unfinished layers enter a server-side carry buffer and are
    folded into a later round with weight ``w(tau) = lam ** tau`` (``tau``
    = rounds of staleness). ``lam=0`` (default) is exact round-synchronous
    semantics — bit-identical to ``backend="dense"``. ``max_age`` drops
    buffered work older than that many rounds; ``buffer_cap`` bounds the
    carry ring buffer (one slot per recent round).

    ``regions`` is the ``hierarchical`` backend's FALLBACK edge-region
    count: when the round context carries no per-device region ids (no
    :class:`repro.fleet.population.Population` behind the cohort source),
    the cohort splits into this many contiguous regions. Cohort region
    ids from a population (``device id % population.regions``) always take
    precedence. ``regions=1`` degenerates to the dense fold, bit-exactly.
    """

    backend: str = "dense"
    chunk_size: int = 16
    mesh: Any = None
    local_iters: int = 1
    l2: float = 0.0
    donate: bool = True
    compression: CompressionConfig = CompressionConfig()
    agg_impl: str = "jnp"
    # buffered (semi-async) staleness knobs
    lam: float = 0.0
    max_age: int = 4
    buffer_cap: int = 4
    # hierarchical backend: fallback edge-region count (see class docstring)
    regions: int = 4
    # round-driver pipelining: "serial" or "prefetch" (one-round lookahead;
    # bit-identical trajectories — see repro.fl.runtime's timeline docs)
    pipeline: str = "serial"

    def __post_init__(self):
        # normalize the legacy compression spec forms (None | mode string |
        # (mode, top_k)) so equality and hashing see one canonical value
        object.__setattr__(self, "compression",
                           make_compression(self.compression))
        if self.backend not in BACKENDS and not hasattr(self.backend,
                                                        "run_round"):
            raise ValueError(f"unknown backend {self.backend!r}; "
                             f"known: {BACKENDS}")
        if self.agg_impl not in AGG_IMPLS:
            raise ValueError(f"unknown agg_impl {self.agg_impl!r}; "
                             f"known: {AGG_IMPLS}")
        if not 0.0 <= float(self.lam) <= 1.0:
            raise ValueError(f"staleness decay lam={self.lam} must be in "
                             f"[0, 1] (w(tau) = lam ** tau)")
        if int(self.max_age) < 1 or int(self.buffer_cap) < 1:
            raise ValueError("max_age and buffer_cap must be >= 1")
        if int(self.regions) < 1:
            raise ValueError(f"regions must be >= 1, got {self.regions}")
        if self.pipeline not in PIPELINES:
            raise ValueError(f"unknown pipeline {self.pipeline!r}; "
                             f"known: {PIPELINES}")

    # ------------------------------------------------------------------
    @classmethod
    def resolve(cls, exec: Optional["ExecSpec"] = None, *,
                base: Optional["ExecSpec"] = None,
                strict: Optional[bool] = None,
                validate: bool = True, **legacy) -> "ExecSpec":
        """THE parsing path every entry point funnels through.

        Starts from ``exec`` (or ``base``, or the defaults), overlays any
        legacy kwarg that was explicitly passed (non-None), and validates
        the result. Entry points keep their old kwargs with ``None``
        sentinels, so ``run_federated(backend="chunked")`` and
        ``run_federated(exec=ExecSpec(backend="chunked"))`` resolve to the
        same spec — and the same trajectory.

        Inapplicable knob combinations (a non-default ``chunk_size`` on a
        backend that never chunks, ``mesh`` off shard_map, staleness knobs
        off ``buffered``, ``agg_impl="pallas"`` under shard_map) emit a
        :class:`UserWarning`; with ``strict=True`` (or the
        ``REPRO_EXEC_STRICT=1`` environment variable) they raise instead —
        extending the HeteroFL+compression guard that already rejects
        un-foldable combinations at round time.
        """
        unknown = set(legacy) - set(_FIELDS)
        if unknown:
            raise TypeError(f"unknown execution kwargs {sorted(unknown)}; "
                            f"known: {_FIELDS}")
        spec = exec if exec is not None else (base if base is not None
                                              else cls())
        if not isinstance(spec, cls):
            raise TypeError(f"exec= expects an ExecSpec, got {type(spec)}")
        overrides = {k: v for k, v in legacy.items() if v is not None}
        if overrides:
            spec = dataclasses.replace(spec, **overrides)
        if validate:
            spec.validate(strict=strict)
        return spec

    def validate(self, *, strict: Optional[bool] = None) -> "ExecSpec":
        """Warn (or raise, under strict) on knobs the backend ignores."""
        if strict is None:
            strict = bool(os.environ.get("REPRO_EXEC_STRICT"))
        defaults = ExecSpec()
        issues = []
        if self.chunk_size != defaults.chunk_size and \
                self.backend != "chunked":
            issues.append(f"chunk_size={self.chunk_size} is ignored by "
                          f"backend={self.backend!r} (chunked only)")
        if self.mesh is not None and self.backend != "shard_map":
            issues.append(f"mesh= is ignored by backend={self.backend!r} "
                          f"(shard_map only)")
        if self.backend != "buffered" and (
                self.lam != defaults.lam or
                self.max_age != defaults.max_age or
                self.buffer_cap != defaults.buffer_cap):
            issues.append(f"staleness knobs (lam={self.lam}, "
                          f"max_age={self.max_age}, "
                          f"buffer_cap={self.buffer_cap}) are ignored by "
                          f"backend={self.backend!r} (buffered only)")
        if self.agg_impl == "pallas" and self.backend == "shard_map":
            issues.append("agg_impl='pallas' is ignored under shard_map "
                          "(shard-local folds run the jnp path)")
        if self.regions != defaults.regions and \
                self.backend != "hierarchical":
            issues.append(f"regions={self.regions} is ignored by "
                          f"backend={self.backend!r} (hierarchical only)")
        for msg in issues:
            if strict:
                raise ValueError(f"ExecSpec: {msg}")
            warnings.warn(f"ExecSpec: {msg}", UserWarning, stacklevel=3)
        return self

    # ------------------------------------------------------------------
    def backend_kwargs(self) -> dict:
        """Constructor kwargs shared by every execution backend."""
        return dict(local_iters=self.local_iters, l2=self.l2,
                    donate=self.donate, compression=self.compression,
                    agg_impl=self.agg_impl)

    def as_dict(self) -> dict:
        """JSON-friendly description (mesh elided to its axis names)."""
        d = {f: getattr(self, f) for f in _FIELDS}
        d["compression"] = dataclasses.asdict(self.compression)
        if self.mesh is not None:
            d["mesh"] = list(getattr(self.mesh, "axis_names", ("?",)))
        return d

    # ------------------------------------------------------------------
    # one CLI surface, derived from the spec (shared by
    # `python -m repro.fleet.scenarios` and `python -m repro.launch.train`)
    @staticmethod
    def add_cli_args(parser) -> None:
        """Install the shared execution-spec argparse group.

        Every flag defaults to None (= keep the resolved spec's value), so
        front-ends can layer CLI overrides on top of their own defaults —
        scenarios on the FleetConfig's spec, the LM driver on ``dense``.
        """
        g = parser.add_argument_group(
            "execution", "execution backend spec (repro.fl.spec.ExecSpec); "
            "unset flags keep the front-end's resolved defaults")
        g.add_argument("--backend", default=None, choices=list(BACKENDS),
                       help="execution backend (repro.fl.backends); "
                            "temporal is the big-arch grad-accumulation "
                            "layout, buffered the semi-async delayed-"
                            "gradient backend")
        g.add_argument("--chunk-size", type=int, default=None,
                       help="client-shard axis chunk (chunked backend)")
        g.add_argument("--no-donate", dest="donate", action="store_false",
                       default=None,
                       help="disable params-buffer donation in round steps")
        g.add_argument("--compression", default=None,
                       choices=list(COMPRESSION_MODES),
                       help="client->server wire compression "
                            "(repro.core.compression): int8 symmetric "
                            "quantization or topk8 sparsification; the "
                            "backend's reduction consumes the compressed "
                            "payload and the solver prices B_u by the "
                            "wire ratio")
        g.add_argument("--topk-frac", type=float, default=None,
                       help="kept fraction per (client, layer) in topk8 "
                            "mode")
        g.add_argument("--agg-impl", default=None, choices=list(AGG_IMPLS),
                       help="aggregation implementation: pallas routes the "
                            "Eq. 5 fold through the fused kernels "
                            "(adel_agg / adel_agg_q8; interpret mode on "
                            "CPU)")
        g.add_argument("--lam", type=float, default=None,
                       help="buffered backend: staleness decay of delayed "
                            "gradients, w(tau) = lam**tau (0 = exact "
                            "round-synchronous semantics)")
        g.add_argument("--max-age", type=int, default=None,
                       help="buffered backend: drop carried work older "
                            "than this many rounds")
        g.add_argument("--buffer-cap", type=int, default=None,
                       help="buffered backend: carry ring-buffer slots "
                            "(one per recent round)")
        g.add_argument("--pipeline", default=None, choices=list(PIPELINES),
                       help="round-driver pipelining: prefetch overlaps "
                            "round t+1's host planning/stacking with round "
                            "t's device step and AOT-warms the round/eval "
                            "steps before round 0 (trajectories stay "
                            "bit-identical to serial)")
        g.add_argument("--compile-cache", default=None, metavar="DIR",
                       help="enable jax's persistent compilation cache at "
                            "DIR (jax_compilation_cache_dir); compiled "
                            "round/eval steps survive process restarts")

    @classmethod
    def from_cli(cls, args, *, base: Optional["ExecSpec"] = None,
                 strict: Optional[bool] = None) -> "ExecSpec":
        """Resolve the spec from parsed :meth:`add_cli_args` flags.

        Also applies the ``--compile-cache DIR`` side flag: it configures
        the jax process (persistent compilation cache), not the spec, so it
        lives here rather than as an ExecSpec field.
        """
        cache_dir = getattr(args, "compile_cache", None)
        if cache_dir:
            import jax
            os.makedirs(cache_dir, exist_ok=True)
            jax.config.update("jax_compilation_cache_dir", cache_dir)
            # cache everything: by default jax skips "fast to compile"
            # computations, which is most of a CPU smoke run
            jax.config.update("jax_persistent_cache_min_compile_time_secs",
                              0.0)
        compression = None
        if args.compression is not None:
            compression = (args.compression if args.topk_frac is None
                           else (args.compression, args.topk_frac))
        elif args.topk_frac is not None and base is not None:
            compression = dataclasses.replace(base.compression,
                                              top_k=float(args.topk_frac))
        return cls.resolve(base=base, strict=strict,
                           backend=args.backend,
                           chunk_size=args.chunk_size,
                           donate=args.donate,
                           compression=compression,
                           agg_impl=args.agg_impl,
                           lam=args.lam, max_age=args.max_age,
                           buffer_cap=args.buffer_cap,
                           pipeline=getattr(args, "pipeline", None))
