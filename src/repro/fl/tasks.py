"""Task adapters: what the unified round runtime trains and evaluates.

A :class:`Task` bundles the three task-specific pieces the
:class:`repro.fl.runtime.RoundRuntime` needs so the round loop itself can
stay workload-agnostic:

* a :class:`repro.fl.runtime.ModelAPI` (init / loss / predict / layer_ids
  / optional HeteroFL width masks),
* a data source in cohort form — classification tasks carry
  ``(U, n, feat...)`` inputs with integer labels, LM tasks carry
  ``(U, n, seq+1)`` token ROWS whose shifted-label split
  ``tok = x[:, :-1], lab = x[:, 1:]`` happens INSIDE the model's loss, so
  :func:`repro.fl.client.sample_client_batches` handles both payloads
  identically (the label array is all-zero and unused for LM),
* eval metrics — classification accuracy + head loss
  (:func:`repro.fl.runtime.eval_metrics`) vs next-token accuracy + token
  CE / perplexity (:func:`lm_eval_metrics`).

:func:`make_lm_model` adapts the big-arch transformer stack
(:mod:`repro.models.transformer`) to the ``ModelAPI`` contract — including
FFN-hidden-width HeteroFL masks, so width-scaling policies run on LM
configs through every execution backend. :func:`lm_task` builds the
synthetic-token-stream task the LM training driver
(:mod:`repro.launch.train`) runs, and :func:`lm_fleet_data` packages the
same streams as a :class:`repro.fleet.engine.FleetData` so LM workloads run
against simulated device fleets (availability, cohort sampling,
re-planning) unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.data.synthetic import make_lm_dataset
from repro.fl.runtime import ModelAPI, StaticCohortSource, eval_metrics

PyTree = Any

__all__ = ["Task", "classification_task", "lm_task", "lm_fleet_data",
           "make_lm_model", "lm_eval_metrics"]


# ---------------------------------------------------------------------------
# the Task bundle
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Task:
    """One workload for the unified round runtime.

    ``client_x``/``client_y``/``counts`` are the pre-stacked population
    (what :class:`repro.fl.runtime.StaticCohortSource` replays every
    round); ``test_x``/``test_y`` the held-out eval split. ``kind``
    selects the eval metrics: ``"classification"`` (accuracy + head loss)
    or ``"lm"`` (next-token accuracy + token CE).
    """

    model: ModelAPI
    client_x: Any
    client_y: Any
    counts: Any
    test_x: Any
    test_y: Any = None
    kind: str = "classification"
    name: str = "task"

    @property
    def n_per_client(self) -> int:
        """Padded per-client pool size (caps the s_max probe)."""
        return int(self.client_y.shape[1])

    def source(self) -> StaticCohortSource:
        return StaticCohortSource(jnp.asarray(self.client_x),
                                  jnp.asarray(self.client_y),
                                  jnp.asarray(self.counts))

    def eval_fn(self) -> Callable[[PyTree], tuple[float, float]]:
        """``params -> (metric, loss)`` for :meth:`RoundRuntime.run`."""
        if self.kind == "lm":
            test = jnp.asarray(self.test_x)
            return lambda params: lm_eval_metrics(self.model, params, test)
        tx, ty = jnp.asarray(self.test_x), jnp.asarray(self.test_y)
        return lambda params: eval_metrics(self.model, params, tx, ty)


def classification_task(model: ModelAPI, client_x, client_y, counts,
                        test_x, test_y, *, name: str = "") -> Task:
    """Wrap pre-stacked classification arrays (the ``run_federated``
    layout) as a :class:`Task`."""
    return Task(model=model, client_x=client_x, client_y=client_y,
                counts=counts, test_x=test_x, test_y=test_y,
                kind="classification", name=name or model.name)


# ---------------------------------------------------------------------------
# LM model adapter over repro.models.transformer
# ---------------------------------------------------------------------------

def _lm_width_masks(cfg: ArchConfig):
    """FFN-hidden-width HeteroFL masks for the stacked transformer params.

    Client u updates the first ``ceil(r_u * F)`` hidden units of every
    block's FFN (dense SwiGLU ``wg``/``wu``/``wd``, MoE experts, shared and
    dense-residual FFNs) — the dominant per-layer compute. Attention /
    SSM / norm / embedding leaves stay full-width (mask of ones), so every
    parameter entry is covered by at least the full-width clients and the
    width-overlap mean (:func:`repro.core.aggregation.hetero_overlap_mean`)
    is always well-defined.
    """
    FFN_PARENTS = ("mlp", "moe", "shared", "dense")

    def width_masks(params: PyTree, ratios: np.ndarray) -> PyTree:
        r = jnp.asarray(ratios, jnp.float32)       # (U,)
        U = r.shape[0]

        def leaf_mask(path, leaf):
            keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
            name = keys[-1] if keys else ""
            parent = keys[-2] if len(keys) >= 2 else ""
            if not (parent in FFN_PARENTS and name in ("wg", "wu", "wd")
                    and leaf.ndim >= 2):
                return jnp.ones((U,) + leaf.shape, jnp.float32)
            # hidden dim F: last axis for wg/wu, second-to-last for wd
            ax = leaf.ndim - 1 if name in ("wg", "wu") else leaf.ndim - 2
            F = leaf.shape[ax]
            keep = jnp.ceil(r * F).astype(jnp.int32)            # (U,)
            m = (jnp.arange(F)[None, :] < keep[:, None]).astype(jnp.float32)
            shape = [U] + [1] * leaf.ndim
            shape[ax + 1] = F
            return jnp.broadcast_to(m.reshape(shape), (U,) + leaf.shape)

        return jax.tree_util.tree_map_with_path(leaf_mask, params)

    return width_masks


def make_lm_model(cfg: ArchConfig, *, moe_aux_coef: float = 0.01,
                  remat: bool = False) -> ModelAPI:
    """A :class:`ModelAPI` over the layered LM backbone.

    The data payload is a ``(b, seq+1)`` int32 token ROW per sample; the
    shifted-label split happens inside ``loss``/``predict``, so the generic
    minibatch sampler and cohort padding treat LM data exactly like
    feature vectors. ``loss`` is the sample-weighted next-token CE (the
    FL runtime weights rows by 1/S_u so the weighted sum is the batch
    mean), plus the MoE load-balance auxiliary when the config routes.
    """
    from repro.models import transformer as tr

    def init(key):
        return tr.init_params(key, cfg)

    def loss(params, x, y, w):
        tok, lab = x[:, :-1], x[:, 1:]
        logits, aux = tr.forward(params, cfg, tok, remat=remat)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, lab[..., None], axis=-1)[..., 0]
        out = jnp.sum(w * nll.mean(-1))
        if cfg.is_moe:
            out = out + moe_aux_coef * aux / cfg.L
        return out

    def predict(params, x):
        # per-position next-token logits for (b, seq+1) rows
        logits, _ = tr.forward(params, cfg, x[:, :-1])
        return logits

    def layer_ids(params):
        return tr.layer_ids(params, cfg)

    return ModelAPI(init=init, loss=loss, predict=predict,
                    layer_ids=layer_ids, L=cfg.n_blocks_total,
                    name=f"lm-{cfg.name}", width_masks=_lm_width_masks(cfg))


def _lm_eval_stats(model: ModelAPI):
    """Cached jit computing (correct tokens, summed token CE) per batch."""
    fn = getattr(model, "_lm_eval_jit", None)
    if fn is None:
        def stats(params, rows):
            logits = model.predict(params, rows)        # (b, S, V)
            labels = rows[:, 1:]
            logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
            nll = -jnp.take_along_axis(logp, labels[..., None],
                                       axis=-1)[..., 0]
            correct = (jnp.argmax(logits, -1) == labels).sum()
            return correct, nll.sum()

        fn = jax.jit(stats)
        model._lm_eval_jit = fn
    return fn


def lm_eval_metrics(model: ModelAPI, params: PyTree, test_rows,
                    test_y=None, *, batch: int = 64
                    ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(next-token accuracy, mean token CE) over held-out token rows.

    ``test_rows``: (n, seq+1) int32. Perplexity is ``exp`` of the returned
    loss. ``test_y`` is accepted (and ignored) so the signature matches
    the classification :func:`repro.fl.runtime.eval_metrics`. Both metrics
    come back as DEVICE scalars: per-batch stats accumulate on-device with
    no host sync, so every batch dispatches asynchronously and the runtime
    can defer the ``float()`` conversion to a report boundary.
    """
    del test_y
    stats = _lm_eval_stats(model)
    n = int(test_rows.shape[0])
    seq = int(test_rows.shape[1]) - 1
    correct = jnp.int32(0)
    nll = jnp.float32(0.0)
    for i in range(0, n, batch):
        c, s = stats(params, test_rows[i:i + batch])
        correct = correct + c
        nll = nll + s
    tokens = n * seq
    return correct / float(tokens), nll / float(tokens)


# ---------------------------------------------------------------------------
# LM tasks: synthetic token streams, static population or fleet
# ---------------------------------------------------------------------------

def _lm_rows(cfg: ArchConfig, n_rows: int, seq: int, seed: int,
             vocab: Optional[int]) -> np.ndarray:
    v = int(vocab or min(cfg.vocab, 2048))
    toks = make_lm_dataset(vocab=v, n_tokens=n_rows * (seq + 1), seed=seed)
    return toks.reshape(n_rows, seq + 1)


def lm_task(cfg: ArchConfig, *, U: int, seq: int = 64, n_seq: int = 96,
            n_eval: int = 64, seed: int = 0, vocab: Optional[int] = None,
            holdout: bool = False, moe_aux_coef: float = 0.01,
            remat: bool = False) -> Task:
    """Synthetic-token-stream LM task: ``U`` clients with contiguous
    stream shards (non-IID by stream position).

    ``client_x``: (U, n_seq, seq+1) token rows; ``client_y`` all-zero
    (labels live inside the rows); eval is next-token accuracy + token CE
    over a FIXED HEAD of each client's pool (the legacy LM driver's eval):
    the synthetic stream's n-gram state is a rolling hash of the full
    history, unrecoverable from one sequence window, so truly held-out
    rows have near-constant CE — the in-pool head is what tracks
    optimization progress. ``holdout=True`` evaluates on disjoint stream
    rows instead.
    """
    rows = _lm_rows(cfg, U * n_seq + (n_eval if holdout else 0), seq, seed,
                    vocab)
    pool = rows[:U * n_seq].reshape(U, n_seq, seq + 1)
    if holdout:
        test = rows[U * n_seq:]
    else:
        head = max(n_eval // U, 1)
        test = pool[:, :head].reshape(-1, seq + 1)
    return Task(model=make_lm_model(cfg, moe_aux_coef=moe_aux_coef,
                                    remat=remat),
                client_x=pool,
                client_y=np.zeros((U, n_seq), np.int32),
                counts=np.full((U,), n_seq, np.int32),
                test_x=test, kind="lm", name=f"lm-{cfg.name}")


def lm_fleet_data(cfg: ArchConfig, n_devices: int, *, seq: int = 32,
                  rows_per_device: int = 24, n_eval: int = 64,
                  seed: int = 0, vocab: Optional[int] = None,
                  holdout: bool = False):
    """Package synthetic token streams as fleet-engine data: LM workloads
    then run against simulated device fleets (availability models, cohort
    sampling, re-planning) exactly like image tasks.

    Returns a :class:`repro.fleet.engine.FleetData` whose ``x`` rows are
    (seq+1)-token sequences and whose labels are all-zero; pair it with
    :func:`make_lm_model` and ``run_fleet(...,
    eval_metrics=lm_eval_metrics)``. Eval rows default to a per-device
    head of the training shards (same rationale as :func:`lm_task`);
    ``holdout=True`` uses disjoint stream rows.
    """
    from repro.fleet.engine import FleetData

    n_rows = n_devices * rows_per_device
    rows = _lm_rows(cfg, n_rows + (n_eval if holdout else 0), seq, seed,
                    vocab)
    x = rows[:n_rows]
    if holdout:
        test = rows[n_rows:]
    else:
        head = max(n_eval // n_devices, 1)
        test = x.reshape(n_devices, rows_per_device,
                         seq + 1)[:, :head].reshape(-1, seq + 1)
    parts = [np.arange(u * rows_per_device, (u + 1) * rows_per_device)
             for u in range(n_devices)]
    return FleetData(x=x, y=np.zeros((n_rows,), np.int32), parts=parts,
                     x_test=test, y_test=np.zeros((len(test),), np.int32))
