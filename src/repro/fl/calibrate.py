"""Pilot-round estimation of the Theorem-1 analysis constants.

The paper (A2/A3) assumes the per-user gradient-variance bounds sigma_u^2
and the gradient-norm bound G^2 are KNOWN to the server when it solves
Problem 2. On a real system they are not — this module estimates them from
a handful of per-sample gradients at the initial model, the natural pilot
phase of Algorithm 1 (server-side, before round 1):

  sigma_u^2 ~= E_i ||grad F_u(w_1; i) - grad F_u(w_1)||^2      (A2 at S=1)
  G^2       ~= max_u E_i ||grad F_u(w_1; i_ref)||^2            (A3)

where i_ref is a reference batch of size ``g_ref_batch`` (the bound that
matters in Lemma 3 is at the operating batch size; per-sample gradients
give the conservative S=1 value when ``g_ref_batch=1``).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import AnalysisConfig

__all__ = ["calibrate_constants"]


def _flat_grad(loss_fn, params, x, y):
    n = y.shape[0]
    g = jax.grad(loss_fn)(params, x, y, jnp.full((n,), 1.0 / n, jnp.float32))
    return jnp.concatenate([l.ravel() for l in jax.tree.leaves(g)])


def calibrate_constants(cfg: AnalysisConfig, model, params, client_x,
                        client_y, n_per_client, *, n_probe: int = 32,
                        g_ref_batch: int = 8) -> AnalysisConfig:
    """Return ``cfg`` with sigma2 / G2 replaced by pilot estimates."""
    U = cfg.U
    sig2 = np.zeros(U, np.float32)
    g2 = np.zeros(U, np.float32)

    @jax.jit
    def stats(xs, ys):
        full = _flat_grad(model.loss, params, xs, ys)

        def one(x1, y1):
            return _flat_grad(model.loss, params, x1[None], y1[None])

        per = jax.vmap(one)(xs, ys)
        var1 = jnp.mean(jnp.sum((per - full[None]) ** 2, -1))
        # E||batch grad||^2 at the reference batch size: full^2 + var1/S_ref
        gref = jnp.sum(full ** 2) + var1 / g_ref_batch
        return var1, gref

    for u in range(U):
        n = min(int(n_per_client[u]), n_probe)
        n = max(n, 2)
        xs = jnp.asarray(client_x[u][:n])
        ys = jnp.asarray(client_y[u][:n])
        v, g = stats(xs, ys)
        sig2[u] = float(v)
        g2[u] = float(g)

    return dataclasses.replace(cfg, sigma2=sig2, G2=float(g2.max()))
