"""Pluggable execution backends for the unified round runtime.

:class:`repro.fl.runtime.RoundRuntime` plans a round (policy, padding,
clock, eval) and hands the padded fixed-shape round inputs to an
:class:`ExecutionBackend`, which owns HOW the cohort's client updates are
computed and aggregated:

* :class:`DenseBackend`     — one vmap over the whole cohort; aggregation is
  :func:`repro.core.aggregation.aggregate_grads` (the original
  ``run_federated`` path).
* :class:`ChunkedBackend`   — the cohort axis is processed ``chunk_size``
  clients at a time; per-chunk partial aggregates from
  :func:`repro.core.aggregation.aggregate_grads_chunk` are summed on the
  host — a software psum that never materializes a full ``(cohort, ...)``
  delta pytree (the original fleet-engine path).
* :class:`ShardMapBackend`  — the chunk loop becomes a REAL client mesh
  axis: ``jax.shard_map`` over :func:`repro.launch.mesh.batch_axes` with
  :func:`repro.core.aggregation.aggregate_grads_local` (``jax.lax.psum``).
  Testable on a CPU host via
  ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
* :class:`TemporalBackend`  — clients are grad-accumulation microbatches:
  ``jax.lax.scan`` over the cohort axis with the Eq. 5 coefficient fold of
  :func:`repro.core.aggregation.weight_by_layer` (the big-arch LM layout
  from ``launch.steps.make_train_step``), so peak memory is ONE delta
  pytree regardless of cohort size. Required for 480B-class architectures.
* :class:`BufferedBackend`  — the semi-async (delayed-gradient) variant of
  dense: layers a straggler did NOT finish by the deadline are banked in a
  server-side carry buffer and folded into a later round's update with
  staleness weight ``lam ** tau`` (see the class docstring). ``lam=0``
  delegates every round to the dense step — trajectory-bit-identical.
* :class:`HierarchicalBackend` — two-tier edge aggregation: the cohort
  partitions into edge regions (region ids from the round context's
  population draw, or a contiguous fallback split), each region computes
  its partial aggregate via the chunk machinery
  (:func:`repro.core.aggregation.aggregate_grads_chunk` /
  ``hetero_overlap_partials`` against GLOBAL counts; int8 wire payloads
  stay compressed region-local), and one global Eq. 5 fold applies the
  summed partials. A single region delegates to the dense step bit-exactly.

All of them produce the same updates up to float summation order, which
``tests/test_backends.py`` asserts end-to-end. Each backend keeps its own
jit cache keyed by ``(bias_correct, hetero)``, so retracing happens at most
once per aggregation rule; HeteroFL width-overlap aggregation
(:func:`repro.core.aggregation.hetero_overlap_partials`) flows through the
same chunk/psum/scan machinery as the layer-wise rule.

Every backend DONATES the incoming ``params`` buffers to its round step
(``jax.jit(..., donate_argnums=0)``): the server update aliases the old
weights in place, halving peak parameter memory on large models. The
runtime's round loop never reads a params buffer after handing it to
``run_round`` — callers that do must construct the backend with
``donate=False``. The chunked backend only donates in its final apply step
(every chunk partial reads the same params).

Backends are selected through :class:`repro.fl.spec.ExecSpec`
(``make_backend(exec=spec, model)``) or by legacy name: ``make_backend(
"dense" | "chunked" | "shard_map" | "temporal" | "buffered", model, ...)``
— both resolve through :meth:`ExecSpec.resolve`, so trajectories are
bit-identical either way.

Compression: every backend accepts a ``compression=`` spec
(:mod:`repro.core.compression` — ``"int8"`` symmetric quantization or
``"topk8"`` sparsification). The compressed payload is what the reduction
CONSUMES: dense/temporal fold it through
:func:`repro.core.compression.aggregate_compressed` (optionally the fused
Pallas ``adel_agg_q8`` kernel via ``agg_impl="pallas"``), chunked's
chunk-sum accumulates partials computed from int8 chunk payloads, and
shard_map quantizes inside the shard-local function so each shard's
reduction reads int8 (the psum itself combines float32 partials).
``agg_impl="pallas"`` also routes UNcompressed dense/temporal aggregation
through ``kernels.ops.adel_aggregate_pallas`` (interpret mode on CPU).
HeteroFL width-overlap rounds are entry-wise means over width masks — not
an Eq. 5 coefficient fold — and reject compression with a ``ValueError``.

Telemetry: every backend carries the runtime's tracer (``set_tracer``,
default :data:`repro.obs.NULL_TRACER`). The fused single-dispatch backends
(dense / shard_map / temporal) emit one ``local_train`` span per round plus
``aggregate_bytes_logical`` / ``aggregate_bytes_wire`` counters (dense
float32 pytree size vs post-compression payload size, both analytic and
exactly deterministic); the chunked backend emits one ``local_train`` span
and one counter pair per chunk and a separate ``aggregate`` span around the
final apply. Active tracers block on step results so spans measure device
work rather than async dispatch — numerics are untouched either way.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

import numpy as np

from repro import obs
from repro.core.aggregation import (aggregate_grads, aggregate_grads_chunk,
                                    aggregate_grads_local,
                                    aggregate_with_coeffs,
                                    hetero_overlap_mean,
                                    hetero_overlap_partials,
                                    layer_coefficients, weight_by_layer)
from repro.core.compression import (aggregate_compressed, compress_deltas,
                                    make_compression, payload_bytes)
from repro.core.straggler import late_arrival_delays, late_p_layers
from repro.fl.client import batched_client_deltas, local_update
# the canonical name tuples live next to ExecSpec (re-exported here for
# back-compat: `from repro.fl.backends import BACKENDS` keeps working)
from repro.fl.spec import AGG_IMPLS, BACKENDS, ExecSpec

try:                                     # jax >= 0.5
    from jax import shard_map as _shard_map
except ImportError:                      # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map
from jax.sharding import PartitionSpec as P

__all__ = ["BACKENDS", "AGG_IMPLS", "ExecSpec", "ExecutionBackend",
           "DenseBackend", "ChunkedBackend", "ShardMapBackend",
           "TemporalBackend", "BufferedBackend", "HierarchicalBackend",
           "make_backend"]

PyTree = Any


def _sub32(w: jnp.ndarray, d: jnp.ndarray) -> jnp.ndarray:
    """dtype-preserving server update for float32 aggregates."""
    return (w.astype(jnp.float32) - d.astype(jnp.float32)).astype(w.dtype)


class ExecutionBackend:
    """Executes one federated round over a padded fixed-shape cohort.

    ``run_round`` receives per-client batches ``xb/yb/wb`` with leading axis
    ``U_pad = cohort_pad(cohort_size)``, the (U_pad, L) contribution mask
    (padded rows all-zero, so they contribute nothing), the (L,)
    zero-contributor probabilities ``p``, the round's learning rate, and —
    for HeteroFL rounds — a width-mask pytree with leading axis U_pad.
    It returns the updated global params.

    With ``donate=True`` (default) the round step donates the ``params``
    argument: the input buffers are invalidated once the step runs, so the
    caller must treat ``run_round`` as consuming its params.
    """

    name = "base"
    #: backends that carry state across rounds (the buffered backend) need
    #: the runtime's per-round :class:`repro.fl.runtime.RoundContext`
    #: (simulated clock + straggler-model rates) passed as ``ctx=``
    needs_ctx = False

    def __init__(self, model, *, local_iters: int = 1, l2: float = 0.0,
                 donate: bool = True, compression=None,
                 agg_impl: str = "jnp"):
        self.model = model
        self.local_iters = int(local_iters)
        self.l2 = float(l2)
        self.donate = bool(donate)
        self.compression = make_compression(compression)
        self.agg_impl = str(agg_impl)
        assert self.agg_impl in AGG_IMPLS, \
            f"unknown agg_impl {agg_impl!r}; known: {AGG_IMPLS}"
        self.tracer = obs.NULL_TRACER
        self._bytes_cache: dict[int, tuple[int, int]] = {}

    def set_tracer(self, tracer) -> None:
        """Attach the runtime's tracer (:class:`repro.obs.Tracer`) so the
        backend's ``local_train`` / ``aggregate`` spans and bytes counters
        land in the same event stream."""
        self.tracer = tracer if tracer is not None else obs.NULL_TRACER

    def _round_bytes(self, params_like: PyTree, U: int) -> tuple[int, int]:
        """Analytic (logical, wire) payload bytes for a U-client reduction
        over this backend's compression config — deterministic, so the
        benchmark gate can match them exactly. ``params_like`` supplies
        leaf shapes only (the round's output params work)."""
        key = int(U)
        if key not in self._bytes_cache:
            ids = self.model.layer_ids(params_like)
            self._bytes_cache[key] = payload_bytes(params_like, ids, key,
                                                   self.compression)
        return self._bytes_cache[key]

    def _count_bytes(self, params_like: PyTree, U: int) -> None:
        logical, wire = self._round_bytes(params_like, U)
        self.tracer.count("aggregate_bytes_logical", logical,
                          backend=self.name)
        self.tracer.count("aggregate_bytes_wire", wire, backend=self.name)

    def _check_rule(self, wmasks) -> None:
        """HeteroFL's width-overlap mean is an entry-wise mean, not an
        Eq. 5 coefficient fold — the quantized wire format has no sound
        dequant-weight for it."""
        if wmasks is not None and self.compression.mode != "none":
            raise ValueError(
                f"compression={self.compression.mode!r} is incompatible "
                f"with HeteroFL width-mask aggregation")

    def _traced_fused(self, step, params, *args):
        """Run a fused train+aggregate jit step under a ``local_train``
        span (the single-dispatch backends cannot split aggregation out of
        the compiled step). An active tracer blocks on the result so the
        span measures device work, not async dispatch; trajectories are
        unchanged."""
        tracer = self.tracer
        if not tracer.active:
            return step(params, *args)
        with tracer.span("local_train", backend=self.name, fused=True):
            out = step(params, *args)
            jax.block_until_ready(out)
        self._count_bytes(out, int(args[3].shape[0]))   # args[3] = mask
        return out

    @property
    def _donate_params(self) -> tuple:
        """donate_argnums for round steps whose argument 0 is params."""
        return (0,) if self.donate else ()

    def cohort_pad(self, U: int) -> int:
        """Smallest padded cohort width >= U this backend can execute."""
        return int(U)

    def reset_state(self) -> None:
        """Clear any cross-round server-side state (carry buffers). The
        runtime calls this at the start of every ``run`` so one backend
        instance can drive several independent trainings. Stateless
        backends are a no-op."""

    def warm_up(self, params: PyTree, xb, yb, wb, mask, p, eta, *,
                bias_correct: bool = True, wmasks: PyTree | None = None,
                ctx=None) -> float:
        """AOT warm-up: trace + compile + execute the round step once for
        the exact argument shapes/dtypes, leaving ``params`` and all
        cross-round state untouched. Returns seconds spent.

        ``jit.lower(...).compile()`` populates XLA's executable cache but
        NOT jax's jit dispatch cache — the first real call would still pay
        the full dispatch-path setup — so the warm-up EXECUTES the real
        ``run_round`` on a private zero-filled copy of ``params``
        (donation-safe) with the caller's round arrays, discards the
        result, and calls :meth:`reset_state` to erase anything the dummy
        round banked (the buffered carry slots, the hierarchical region
        census). Host-side branch decisions (buffered's bank-or-not,
        hierarchical's region split) read the real ``mask``/``ctx``
        values, so the variant round 0 will run is the variant that gets
        compiled. Telemetry is suppressed for the dummy round.
        """
        t0 = obs.now()
        dummy = jax.tree.map(lambda a: jnp.zeros(jnp.shape(a),
                                                 jnp.result_type(a)), params)
        tracer = self.tracer
        self.tracer = obs.NULL_TRACER
        try:
            out = self.run_round(dummy, xb, yb, wb, mask, p, eta,
                                 bias_correct=bias_correct, wmasks=wmasks,
                                 ctx=ctx)
            jax.block_until_ready(out)
        finally:
            self.tracer = tracer
            self.reset_state()
        return obs.now() - t0

    def run_round(self, params: PyTree, xb, yb, wb, mask, p, eta, *,
                  bias_correct: bool, wmasks: PyTree | None = None,
                  ctx=None) -> PyTree:
        raise NotImplementedError

    def describe(self) -> dict:
        return {"backend": self.name, "donate": self.donate,
                "compression": self.compression.mode,
                "agg_impl": self.agg_impl}

    # shared sub-computations -------------------------------------------
    def _deltas(self, params, xb, yb, wb, eta):
        return batched_client_deltas(self.model.loss, params, xb, yb, wb,
                                     eta, local_iters=self.local_iters,
                                     l2=self.l2)


class DenseBackend(ExecutionBackend):
    """Whole cohort in one vmap + one monolithic aggregation."""

    name = "dense"

    def __init__(self, model, *, local_iters: int = 1, l2: float = 0.0,
                 donate: bool = True, compression=None,
                 agg_impl: str = "jnp"):
        super().__init__(model, local_iters=local_iters, l2=l2, donate=donate,
                         compression=compression, agg_impl=agg_impl)
        self._steps: dict[tuple, Callable] = {}

    def _step(self, bias_correct: bool, hetero: bool) -> Callable:
        key = (bias_correct, hetero)
        if key not in self._steps:
            comp = self.compression

            def step(params, xb, yb, wb, mask, p, eta, wmasks):
                deltas = self._deltas(params, xb, yb, wb, eta)
                ids = self.model.layer_ids(params)
                if hetero:
                    num, den = hetero_overlap_partials(deltas, wmasks,
                                                       mask[:, 0])
                    agg = hetero_overlap_mean(num, den)
                elif comp.mode != "none":
                    # the reduction consumes the int8 wire payload: the
                    # float32 delta tree never feeds the aggregation
                    payload = compress_deltas(deltas, ids, comp)
                    agg = aggregate_compressed(
                        payload, params, ids, mask, p, cfg=comp,
                        bias_correct=bias_correct, agg_impl=self.agg_impl)
                    return jax.tree.map(_sub32, params, agg)
                elif self.agg_impl == "pallas":
                    from repro.kernels.ops import adel_aggregate_pallas
                    agg = adel_aggregate_pallas(deltas, ids, mask, p,
                                                bias_correct=bias_correct)
                else:
                    agg = aggregate_grads(deltas, ids, mask, p,
                                          bias_correct=bias_correct)
                return jax.tree.map(lambda w, d: w - d, params, agg)

            self._steps[key] = jax.jit(step,
                                       donate_argnums=self._donate_params)
        return self._steps[key]

    def run_round(self, params, xb, yb, wb, mask, p, eta, *,
                  bias_correct, wmasks=None, ctx=None):
        self._check_rule(wmasks)
        step = self._step(bool(bias_correct), wmasks is not None)
        return self._traced_fused(step, params, xb, yb, wb, mask, p, eta,
                                  wmasks)


class ChunkedBackend(ExecutionBackend):
    """Sequential software psum over a client-shard axis.

    The cohort is padded to a ``chunk_size`` multiple; each chunk's partial
    aggregate uses the GLOBAL per-layer contributor counts, so summing the
    partials over chunks equals the dense aggregation on the concatenated
    client axis. A single-chunk cohort falls through to the dense step.

    Every chunk partial reads the same ``params``, so only the final apply
    step (``params - agg``) donates the params buffers.
    """

    name = "chunked"

    def __init__(self, model, *, chunk_size: int = 16, local_iters: int = 1,
                 l2: float = 0.0, donate: bool = True, compression=None,
                 agg_impl: str = "jnp"):
        super().__init__(model, local_iters=local_iters, l2=l2, donate=donate,
                         compression=compression, agg_impl=agg_impl)
        self.chunk_size = max(int(chunk_size), 1)
        self._dense = DenseBackend(model, local_iters=local_iters, l2=l2,
                                   donate=donate, compression=compression,
                                   agg_impl=agg_impl)
        self._chunks: dict[tuple, Callable] = {}
        self._folds: dict[bool, Callable] = {}
        self._payload_step = None
        self._apply = jax.jit(
            lambda params, agg: jax.tree.map(lambda w, d: w - d, params, agg),
            donate_argnums=self._donate_params)
        self._apply32 = jax.jit(
            lambda params, agg: jax.tree.map(_sub32, params, agg),
            donate_argnums=self._donate_params)
        self._apply_hetero = jax.jit(
            lambda params, num, den: jax.tree.map(
                lambda w, d: w - d, params, hetero_overlap_mean(num, den)),
            donate_argnums=self._donate_params)

    def cohort_pad(self, U: int) -> int:
        c = min(self.chunk_size, int(U))   # never vmap dead padding
        return -(-int(U) // c) * c

    def set_tracer(self, tracer) -> None:
        super().set_tracer(tracer)
        self._dense.set_tracer(tracer)     # single-chunk fall-through

    def _chunk_step(self, bias_correct: bool, hetero: bool) -> Callable:
        key = (bias_correct, hetero)
        if key not in self._chunks:
            # NEVER donate params here: the same buffers feed every chunk
            @jax.jit
            def chunk_partial(params, xb, yb, wb, mask_c, p, eta, counts,
                              wmasks_c):
                deltas = self._deltas(params, xb, yb, wb, eta)
                ids = self.model.layer_ids(params)
                if hetero:
                    return hetero_overlap_partials(deltas, wmasks_c,
                                                   mask_c[:, 0])
                return aggregate_grads_chunk(deltas, ids, mask_c, p, counts,
                                             bias_correct=bias_correct)

            self._chunks[key] = chunk_partial
        return self._chunks[key]

    def _payload(self) -> Callable:
        """jit step producing one chunk's compressed wire payload — the
        int8 tuples are what crosses the jit boundary and what the
        chunk-sum consumes."""
        if self._payload_step is None:
            comp = self.compression

            # NEVER donate params here: the same buffers feed every chunk
            @jax.jit
            def chunk_payload(params, xb, yb, wb, eta):
                deltas = self._deltas(params, xb, yb, wb, eta)
                ids = self.model.layer_ids(params)
                return compress_deltas(deltas, ids, comp)

            self._payload_step = chunk_payload
        return self._payload_step

    def _fold(self, bias_correct: bool) -> Callable:
        """jit fold: dequantize + Eq. 5 weight one chunk payload (against
        GLOBAL counts) and accumulate into the float32 running aggregate.
        The accumulator is donated — the fold updates it in place."""
        if bias_correct not in self._folds:
            comp = self.compression

            def fold(acc, params, payload, mask_c, p, counts):
                ids = self.model.layer_ids(params)
                part = aggregate_compressed(
                    payload, params, ids, mask_c, p, cfg=comp, counts=counts,
                    bias_correct=bias_correct, agg_impl=self.agg_impl)
                return jax.tree.map(jnp.add, acc, part)

            self._folds[bias_correct] = jax.jit(fold, donate_argnums=(0,))
        return self._folds[bias_correct]

    def _run_round_compressed(self, params, xb, yb, wb, mask, p, eta, *,
                              bias_correct, U, c):
        payload_step = self._payload()
        fold = self._fold(bool(bias_correct))
        counts = mask.sum(0)                   # (L,) global contributors
        tracer = self.tracer
        acc = jax.tree.map(lambda w: jnp.zeros(w.shape, jnp.float32), params)
        for c0 in range(0, U, c):
            sl = slice(c0, c0 + c)
            with tracer.span("local_train", backend=self.name,
                             chunk=c0 // c):
                payload = payload_step(params, xb[sl], yb[sl], wb[sl], eta)
                if tracer.active:
                    jax.block_until_ready(payload)
            if tracer.active:
                self._count_bytes(params, c)
            acc = fold(acc, params, payload, mask[sl], p, counts)
        with tracer.span("aggregate", backend=self.name, chunks=-(-U // c)):
            out = self._apply32(params, acc)
            if tracer.active:
                jax.block_until_ready(out)
        return out

    def run_round(self, params, xb, yb, wb, mask, p, eta, *,
                  bias_correct, wmasks=None, ctx=None):
        self._check_rule(wmasks)
        U = int(mask.shape[0])
        c = min(self.chunk_size, U)
        if U <= c:
            return self._dense.run_round(params, xb, yb, wb, mask, p, eta,
                                         bias_correct=bias_correct,
                                         wmasks=wmasks)
        if self.compression.mode != "none":
            return self._run_round_compressed(params, xb, yb, wb, mask, p,
                                              eta, bias_correct=bias_correct,
                                              U=U, c=c)
        hetero = wmasks is not None
        step = self._chunk_step(bool(bias_correct), hetero)
        counts = mask.sum(0)                       # (L,) global contributors
        tracer = self.tracer
        num = den = agg = None
        for c0 in range(0, U, c):
            sl = slice(c0, c0 + c)
            wm_c = (None if not hetero
                    else jax.tree.map(lambda m: m[sl], wmasks))
            with tracer.span("local_train", backend=self.name,
                             chunk=c0 // c):
                part = step(params, xb[sl], yb[sl], wb[sl], mask[sl], p, eta,
                            counts, wm_c)
                if tracer.active:
                    jax.block_until_ready(part)
            if tracer.active:
                self._count_bytes(params, c)
            if hetero:
                n_p, d_p = part
                num = n_p if num is None else jax.tree.map(jnp.add, num, n_p)
                den = d_p if den is None else jax.tree.map(jnp.add, den, d_p)
            else:
                agg = part if agg is None else jax.tree.map(jnp.add, agg, part)
        with tracer.span("aggregate", backend=self.name,
                         chunks=-(-U // c)):
            out = (self._apply_hetero(params, num, den) if hetero
                   else self._apply(params, agg))
            if tracer.active:
                jax.block_until_ready(out)
        return out

    def describe(self):
        return {**super().describe(), "chunk_size": self.chunk_size}


class ShardMapBackend(ExecutionBackend):
    """The chunk axis as a real client mesh axis: shard_map + lax.psum.

    The cohort is padded to a multiple of the mesh's batch shards; every
    shard computes its clients' deltas and local partials, and
    ``jax.lax.psum`` over :func:`repro.launch.mesh.batch_axes` combines
    counts and weighted sums — the hardware form of the chunk loop.
    """

    name = "shard_map"

    def __init__(self, model, *, mesh=None, local_iters: int = 1,
                 l2: float = 0.0, donate: bool = True, compression=None,
                 agg_impl: str = "jnp"):
        super().__init__(model, local_iters=local_iters, l2=l2, donate=donate,
                         compression=compression, agg_impl=agg_impl)
        self._mesh = mesh
        self._steps: dict[tuple, Callable] = {}

    @property
    def mesh(self):
        if self._mesh is None:
            from repro.launch.mesh import make_client_mesh
            self._mesh = make_client_mesh()
        return self._mesh

    @property
    def client_axes(self) -> tuple:
        from repro.launch.mesh import batch_axes
        return batch_axes(self.mesh)

    @property
    def n_shards(self) -> int:
        from repro.launch.mesh import batch_shards
        return batch_shards(self.mesh)

    def cohort_pad(self, U: int) -> int:
        n = self.n_shards
        return -(-int(U) // n) * n

    def _step(self, bias_correct: bool, hetero: bool) -> Callable:
        key = (bias_correct, hetero)
        if key not in self._steps:
            mesh = self.mesh
            ax = tuple(self.client_axes)
            model = self.model
            comp = self.compression

            def local_fn(params, xb, yb, wb, mask_l, p, eta, wmasks_l):
                deltas = self._deltas(params, xb, yb, wb, eta)
                ids = model.layer_ids(params)
                if hetero:
                    num, den = hetero_overlap_partials(deltas, wmasks_l,
                                                       mask_l[:, 0])
                    num = jax.lax.psum(num, ax)
                    den = jax.lax.psum(den, ax)
                    agg = hetero_overlap_mean(num, den)
                elif comp.mode != "none":
                    # each shard's reduction consumes its clients' int8
                    # payload; the psum combines float32 shard partials
                    # (the jnp fold — Pallas inside shard_map is not
                    # supported in interpret mode)
                    counts = jax.lax.psum(mask_l.sum(0), ax)
                    payload = compress_deltas(deltas, ids, comp)
                    part = aggregate_compressed(
                        payload, params, ids, mask_l, p, cfg=comp,
                        counts=counts, bias_correct=bias_correct,
                        agg_impl="jnp")
                    agg = jax.lax.psum(part, ax)
                    return jax.tree.map(_sub32, params, agg)
                else:
                    agg = aggregate_grads_local(deltas, ids, mask_l, p, ax,
                                                bias_correct=bias_correct)
                return jax.tree.map(lambda w, d: w - d, params, agg)

            spec_c = P(ax)      # leading client axis sharded over batch axes
            spec_r = P()        # replicated
            wm_spec = spec_c if hetero else spec_r
            self._steps[key] = jax.jit(_shard_map(
                local_fn, mesh=mesh,
                in_specs=(spec_r, spec_c, spec_c, spec_c, spec_c, spec_r,
                          spec_r, wm_spec),
                out_specs=spec_r, check_rep=False),
                donate_argnums=self._donate_params)
        return self._steps[key]

    def run_round(self, params, xb, yb, wb, mask, p, eta, *,
                  bias_correct, wmasks=None, ctx=None):
        self._check_rule(wmasks)
        step = self._step(bool(bias_correct), wmasks is not None)
        return self._traced_fused(step, params, xb, yb, wb, mask, p, eta,
                                  wmasks)

    def describe(self):
        return {**super().describe(), "shards": self.n_shards,
                "mesh_axes": list(self.mesh.axis_names)}


class TemporalBackend(ExecutionBackend):
    """Clients as grad-accumulation microbatches: ``lax.scan`` over the
    cohort axis, folding the Eq. 5 coefficients into the accumulation.

    This is the big-arch LM client layout of
    ``repro.launch.steps.make_train_step(mode="temporal")`` hoisted into the
    unified runtime: each scan step runs ONE client's local update and adds
    its coefficient-weighted delta (:func:`repro.core.aggregation.
    weight_by_layer`) into a single f32 accumulator, so peak memory is one
    delta pytree regardless of cohort size — the layout required for the
    480B-class architectures. HeteroFL rounds accumulate the width-overlap
    (num, den) partials instead and finish with
    :func:`repro.core.aggregation.hetero_overlap_mean`.
    """

    name = "temporal"

    def __init__(self, model, *, local_iters: int = 1, l2: float = 0.0,
                 donate: bool = True, compression=None,
                 agg_impl: str = "jnp"):
        super().__init__(model, local_iters=local_iters, l2=l2, donate=donate,
                         compression=compression, agg_impl=agg_impl)
        self._steps: dict[tuple, Callable] = {}

    def _step(self, bias_correct: bool, hetero: bool) -> Callable:
        key = (bias_correct, hetero)
        if key not in self._steps:
            model = self.model

            def delta_u(params, x_u, y_u, w_u, eta):
                return local_update(model.loss, params, x_u, y_u, w_u, eta,
                                    local_iters=self.local_iters, l2=self.l2)

            def step(params, xb, yb, wb, mask, p, eta, wmasks):
                ids = model.layer_ids(params)
                zeros32 = jax.tree.map(
                    lambda w: jnp.zeros(w.shape, jnp.float32), params)
                if hetero:
                    part = mask[:, 0]                       # (U,)

                    def body(acc, inp):
                        x_u, y_u, w_u, pt_u, wm_u = inp
                        d = delta_u(params, x_u, y_u, w_u, eta)
                        num, den = acc
                        num = jax.tree.map(
                            lambda n, dd, wm: n + pt_u * wm
                            * dd.astype(jnp.float32), num, d, wm_u)
                        den = jax.tree.map(
                            lambda dn, wm: dn + pt_u * wm, den, wm_u)
                        return (num, den), None

                    (num, den), _ = jax.lax.scan(
                        body, (zeros32, zeros32), (xb, yb, wb, part, wmasks))
                    agg = hetero_overlap_mean(num, den)
                else:
                    coeffs = layer_coefficients(mask, p,
                                                bias_correct=bias_correct)
                    comp = self.compression

                    if comp.mode != "none":
                        # one client per scan step: quantize the delta to
                        # its wire form, then dequant+weight+accumulate
                        # against this client's GLOBAL-count coefficient
                        # row — peak memory stays one delta pytree
                        def body(acc, inp):
                            x_u, y_u, w_u, c_row = inp
                            d = delta_u(params, x_u, y_u, w_u, eta)
                            d1 = jax.tree.map(
                                lambda dd: dd.astype(jnp.float32)[None], d)
                            payload = compress_deltas(d1, ids, comp)
                            dw = aggregate_compressed(
                                payload, params, ids, None, None, cfg=comp,
                                coeffs=c_row[None],
                                agg_impl=self.agg_impl)
                            return jax.tree.map(jnp.add, acc, dw), None
                    elif self.agg_impl == "pallas":
                        from repro.kernels.ops import adel_aggregate_pallas

                        def body(acc, inp):
                            x_u, y_u, w_u, c_row = inp
                            d = delta_u(params, x_u, y_u, w_u, eta)
                            d1 = jax.tree.map(
                                lambda dd: dd.astype(jnp.float32)[None], d)
                            dw = adel_aggregate_pallas(d1, ids, None, None,
                                                       coeffs=c_row[None])
                            return jax.tree.map(jnp.add, acc, dw), None
                    else:
                        def body(acc, inp):
                            x_u, y_u, w_u, c_row = inp
                            d = delta_u(params, x_u, y_u, w_u, eta)
                            dw = jax.tree.map(
                                lambda dd, idl: weight_by_layer(
                                    dd.astype(jnp.float32), idl, c_row),
                                d, ids)
                            return jax.tree.map(jnp.add, acc, dw), None

                    agg, _ = jax.lax.scan(body, zeros32,
                                          (xb, yb, wb, coeffs))
                return jax.tree.map(
                    lambda w, d: (w.astype(jnp.float32)
                                  - d).astype(w.dtype), params, agg)

            self._steps[key] = jax.jit(step,
                                       donate_argnums=self._donate_params)
        return self._steps[key]

    def run_round(self, params, xb, yb, wb, mask, p, eta, *,
                  bias_correct, wmasks=None, ctx=None):
        self._check_rule(wmasks)
        step = self._step(bool(bias_correct), wmasks is not None)
        return self._traced_fused(step, params, xb, yb, wb, mask, p, eta,
                                  wmasks)


class BufferedBackend(DenseBackend):
    """Semi-async delayed-gradient execution: stragglers' unfinished layers
    are banked and folded into later rounds with staleness decay.

    ADEL-FL's round-synchronous aggregation discards every layer a client
    did not finish by the deadline. Following the delayed-gradient line
    (*Stragglers Are Not Disaster*, arxiv 2102.06329; *TimelyFL*, arxiv
    2304.06947), this backend keeps that work: the straggler continues its
    backward pass past the deadline, and the layers it finishes LATE —
    exactly the complement ``1 - mask`` of the round's contribution mask —
    arrive at the server once the simulated clock reaches

        ``arrival_u = round_end + max(L - z_u, 0) * S_u / P_u + B_u``

    (:func:`repro.core.straggler.late_arrival_delays` — the same
    exponential per-layer clock that makes ``z_u`` Poisson). Each later
    round ``t`` folds every buffered contribution whose arrival the clock
    has passed into the server update with weight ``lam ** tau``
    (``tau = t - work_round >= 1``), through the Eq. 5 layer-wise
    coefficient path: the banked coefficients are
    :func:`repro.core.aggregation.layer_coefficients` evaluated on the
    LATE mask with the late-set zero-contributor probabilities
    :func:`repro.core.straggler.late_p_layers`, so at weight 1 the fold is
    an unbiased estimate of the late set's FedAvg layer mean
    (``tests/test_unbiasedness.py``).

    The carry buffer is a ring of ``buffer_cap`` slots (one per banked
    round), each holding device payloads — float32 delta leaves, or, under
    ``compression=``, the int8 WIRE tuples the on-time reduction already
    computed (the buffer never re-materializes dequantized f32; the fold
    goes through :func:`repro.core.compression.aggregate_compressed` with
    explicit coefficients). Slot payloads are fresh jit outputs and are
    never donated, so they survive the params donation of later round
    steps. Work older than ``max_age`` rounds, or evicted by the ring, is
    dropped (counted in the ``carried_dropped`` ledger column).

    ``lam=0`` (the default) delegates every round to the inherited dense
    step — trajectory-BIT-identical to ``backend="dense"``, which the
    backend-equivalence suite asserts. ``lam>0`` needs the runtime's
    :class:`repro.fl.runtime.RoundContext` (``ctx=``) for the simulated
    clock and straggler rates, and rejects HeteroFL width-mask rounds
    (the width-overlap mean has no late-set analogue).
    """

    name = "buffered"

    def __init__(self, model, *, lam: float = 0.0, max_age: int = 4,
                 buffer_cap: int = 4, local_iters: int = 1, l2: float = 0.0,
                 donate: bool = True, compression=None,
                 agg_impl: str = "jnp"):
        super().__init__(model, local_iters=local_iters, l2=l2,
                         donate=donate, compression=compression,
                         agg_impl=agg_impl)
        if not 0.0 <= float(lam) <= 1.0:
            raise ValueError(f"lam={lam} must be in [0, 1]")
        self.lam = float(lam)
        self.max_age = int(max_age)
        self.buffer_cap = int(buffer_cap)
        self._mains: dict[tuple, Callable] = {}
        self._fold_step = None
        self._slots: list[dict] = []     # FIFO ring of banked rounds
        self.last_carry: dict = {}

    @property
    def needs_ctx(self) -> bool:        # type: ignore[override]
        return self.lam > 0.0

    def reset_state(self) -> None:
        self._slots = []
        self.last_carry = {}

    def describe(self):
        return {**super().describe(), "lam": self.lam,
                "max_age": self.max_age, "buffer_cap": self.buffer_cap}

    # jit steps ---------------------------------------------------------
    def _main(self, bias_correct: bool, bank: bool) -> Callable:
        """Fused local-train + on-time Eq. 5 aggregate, optionally also
        returning the round's bankable payload (the wire format under
        compression, float32 delta leaves otherwise)."""
        key = (bias_correct, bank)
        if key not in self._mains:
            comp = self.compression

            def step(params, xb, yb, wb, mask, p, eta):
                deltas = self._deltas(params, xb, yb, wb, eta)
                ids = self.model.layer_ids(params)
                banked = None
                if comp.mode != "none":
                    payload = compress_deltas(deltas, ids, comp)
                    agg = aggregate_compressed(
                        payload, params, ids, mask, p, cfg=comp,
                        bias_correct=bias_correct, agg_impl=self.agg_impl)
                    banked = payload       # the SAME int8 wire tuples
                else:
                    banked = jax.tree.map(
                        lambda d: d.astype(jnp.float32), deltas)
                    if self.agg_impl == "pallas":
                        from repro.kernels.ops import adel_aggregate_pallas
                        agg = adel_aggregate_pallas(
                            deltas, ids, mask, p, bias_correct=bias_correct)
                    else:
                        agg = aggregate_grads(deltas, ids, mask, p,
                                              bias_correct=bias_correct)
                new = jax.tree.map(_sub32, params, agg)
                return (new, banked) if bank else new

            self._mains[key] = jax.jit(step,
                                       donate_argnums=self._donate_params)
        return self._mains[key]

    def _fold(self) -> Callable:
        """Fold one carry slot into params: ``params - sum_u (c_late[u] *
        w[u]) . delta_u`` — w carries the staleness decay and arrival
        eligibility. Only params is donated; the slot payload may fold
        again (clients of one round arrive at different times)."""
        if self._fold_step is None:
            comp = self.compression

            def fold(params, banked, c_late, w):
                ids = self.model.layer_ids(params)
                coeffs = c_late * w[:, None]
                if comp.mode != "none":
                    agg = aggregate_compressed(
                        banked, params, ids, None, None, cfg=comp,
                        coeffs=coeffs, agg_impl=self.agg_impl)
                elif self.agg_impl == "pallas":
                    from repro.kernels.ops import adel_aggregate_pallas
                    agg = adel_aggregate_pallas(banked, ids, None, None,
                                                coeffs=coeffs)
                else:
                    agg = aggregate_with_coeffs(banked, ids, coeffs)
                return jax.tree.map(_sub32, params, agg)

            self._fold_step = jax.jit(fold,
                                      donate_argnums=self._donate_params)
        return self._fold_step

    # round -------------------------------------------------------------
    def run_round(self, params, xb, yb, wb, mask, p, eta, *,
                  bias_correct, wmasks=None, ctx=None):
        if self.lam == 0.0:
            # exact round-synchronous semantics: the inherited dense step,
            # bit for bit (no carry, no extra jit)
            return super().run_round(params, xb, yb, wb, mask, p, eta,
                                     bias_correct=bias_correct,
                                     wmasks=wmasks)
        if wmasks is not None:
            raise ValueError("buffered backend with lam>0 is incompatible "
                             "with HeteroFL width-mask aggregation")
        if ctx is None:
            raise ValueError("buffered backend with lam>0 needs the "
                             "runtime's RoundContext (ctx=): the carry "
                             "buffer is driven by the simulated clock")
        self._check_rule(wmasks)
        L = int(mask.shape[1])
        U_pad = int(mask.shape[0])
        U_act = int(ctx.U_act)
        t = int(ctx.t)
        mask_h = np.asarray(mask, np.float32)
        depth = mask_h.sum(1)                         # (U_pad,) realized z
        real = np.arange(U_pad) < U_act
        late_rows = real & (depth < L)

        # 1. fold decisions, entirely host-side (slot metadata): which
        #    banked clients' arrivals has the simulated clock passed?
        folds, dropped, stale = [], 0, {}
        for slot in self._slots:
            pend = slot["pending"]
            if not pend.any():
                continue
            tau = t - slot["round"]
            if tau > self.max_age:
                dropped += int(pend.sum())
                pend[:] = False
                continue
            elig = pend & (slot["arrival"] <= float(ctx.sim_end))
            if elig.any():
                w = np.where(elig, np.float32(self.lam) ** tau,
                             np.float32(0.0)).astype(np.float32)
                folds.append((slot, w))
                stale[tau] = stale.get(tau, 0) + int(elig.sum())
                pend &= ~elig

        # 2. this round's late-set coefficients: Eq. 5 on the COMPLEMENT
        #    mask with the late-set zero-contributor probabilities
        bank = bool(late_rows.any())
        if bank:
            late_mask = jnp.asarray((1.0 - mask_h) * real[:, None],
                                    jnp.float32)
            p_late = late_p_layers(jnp.asarray(ctx.lam, jnp.float32), L)
            c_late = layer_coefficients(late_mask, p_late,
                                        bias_correct=bool(bias_correct))

        # 3. the fused train + on-time aggregate (+ bankable payload)
        tracer = self.tracer
        step = self._main(bool(bias_correct), bank)
        with tracer.span("local_train", backend=self.name, fused=True):
            out = step(params, xb, yb, wb, mask, p, eta)
            if tracer.active:
                jax.block_until_ready(out)
        params, banked = out if bank else (out, None)
        if tracer.active:
            self._count_bytes(params, U_pad)

        # 4. fold every arrived carry slot (params flows through, donated)
        if folds:
            fold = self._fold()
            with tracer.span("aggregate", backend=self.name,
                             carried=sum(int((w > 0).sum())
                                         for _, w in folds)):
                for slot, w in folds:
                    params = fold(params, slot["banked"], slot["c_late"],
                                  jnp.asarray(w))
                if tracer.active:
                    jax.block_until_ready(params)

        # 5. bank this round's late work (ring eviction drops the oldest)
        if bank:
            delays = late_arrival_delays(depth[:U_act], ctx.layer_s, ctx.B,
                                         L)
            arrival = np.full(U_pad, np.inf, np.float32)
            arrival[:U_act] = float(ctx.sim_end) + np.asarray(delays)
            if len(self._slots) >= self.buffer_cap:
                evicted = self._slots.pop(0)
                dropped += int(evicted["pending"].sum())
            self._slots.append({"round": t, "banked": banked,
                                "c_late": c_late, "arrival": arrival,
                                "pending": late_rows.copy()})

        carried_in = sum(stale.values())
        carried_out = sum(int(s["pending"].sum()) for s in self._slots)
        self.last_carry = {"carried_in": carried_in,
                           "carried_out": carried_out,
                           "carried_dropped": dropped,
                           "stale": stale}
        tracer.count("carried_in", carried_in, backend=self.name)
        tracer.count("carried_out", carried_out, backend=self.name)
        if dropped:
            tracer.count("carried_dropped", dropped, backend=self.name)
        return params


class HierarchicalBackend(ChunkedBackend):
    """Two-tier edge aggregation: per-region partials + one global fold.

    Million-device deployments do not reduce every client update at one
    server: clients report to an edge aggregator for their REGION, and
    only the per-region partial aggregates cross the wide-area network
    (hierarchical FL à la HierFAVG, arxiv 1905.06641). This backend
    reproduces that topology inside the unified runtime:

    1. The padded cohort is partitioned into edge regions. Region ids come
       from the round context (``ctx.regions`` — the population draw's
       ``device_id % Population.regions``); without a context the cohort
       splits into ``regions`` contiguous slices, so the backend works
       under plain ``run_federated`` too.
    2. Each region runs its clients' local updates and computes ONE
       partial aggregate with the chunk machinery —
       :func:`repro.core.aggregation.aggregate_grads_chunk` (or
       ``hetero_overlap_partials`` for HeteroFL rounds) evaluated against
       the GLOBAL per-layer contributor counts, so summing the partials
       over regions is exactly the flat Eq. 5 fold on the whole cohort.
       Under ``compression=``, each region's int8 wire payload is
       dequantized+weighted+accumulated region-locally
       (:func:`repro.core.compression.aggregate_compressed`) — only the
       float32 partial crosses region boundaries, never per-client wire
       tuples.
    3. The server applies the summed partials in one donated step.

    Regions are gathered through padded index maps (region width rounded
    up to a multiple of 8, pad slots pointing at row 0 with a validity
    column zeroing their mask rows), so jit retraces at most once per
    distinct padded region width rather than per region census.

    A single-region round (``regions=1``, or every sampled device in one
    region) delegates to the dense step — bit-identical to
    ``backend="dense"``, which ``tests/test_population.py`` asserts.
    ``last_regions`` exposes the round's region census to the runtime's
    ledger (``regions`` / ``region_max`` / ``region_pad`` columns).
    """

    name = "hierarchical"
    needs_ctx = True

    def __init__(self, model, *, regions: int = 4, chunk_size: int = 16,
                 local_iters: int = 1, l2: float = 0.0, donate: bool = True,
                 compression=None, agg_impl: str = "jnp"):
        super().__init__(model, chunk_size=chunk_size,
                         local_iters=local_iters, l2=l2, donate=donate,
                         compression=compression, agg_impl=agg_impl)
        self.regions = max(int(regions), 1)
        self.last_regions: dict = {}

    def cohort_pad(self, U: int) -> int:
        # regions pad internally (multiple-of-8 gathers); the cohort axis
        # itself needs no chunk-multiple padding
        return int(U)

    def reset_state(self) -> None:
        self.last_regions = {}

    def describe(self):
        return {**super().describe(), "regions": self.regions}

    def _region_groups(self, ctx, U: int) -> list[np.ndarray]:
        """Per-region member indices into the padded cohort axis.

        Pad rows (>= U_act) keep the region id of the fallback split or
        id 0; their mask rows are all-zero either way, so they contribute
        nothing regardless of which region gathers them.
        """
        ra = getattr(ctx, "regions", None) if ctx is not None else None
        if ra is not None:
            ra = np.asarray(ra, np.int64)
            rid = np.zeros(U, np.int64)
            rid[:min(len(ra), U)] = ra[:U]
        else:
            rid = (np.arange(U) * self.regions) // max(U, 1)
        return [np.flatnonzero(rid == g) for g in np.unique(rid)]

    def run_round(self, params, xb, yb, wb, mask, p, eta, *,
                  bias_correct, wmasks=None, ctx=None):
        self._check_rule(wmasks)
        U = int(mask.shape[0])
        groups = self._region_groups(ctx, U)
        if len(groups) <= 1:
            self.last_regions = {"regions": 1, "region_max": U,
                                 "region_pad": U}
            return self._dense.run_round(params, xb, yb, wb, mask, p, eta,
                                         bias_correct=bias_correct,
                                         wmasks=wmasks)
        rmax = max(len(g) for g in groups)
        r_pad = max(-(-rmax // 8) * 8, 8)
        self.last_regions = {"regions": len(groups), "region_max": rmax,
                             "region_pad": r_pad}
        counts = mask.sum(0)              # (L,) GLOBAL contributor counts
        tracer = self.tracer
        hetero = wmasks is not None
        gathers = []
        for g in groups:
            idx = np.zeros(r_pad, np.int64)
            idx[:len(g)] = g
            valid = np.zeros((r_pad, 1), np.float32)
            valid[:len(g)] = 1.0
            gathers.append((idx, jnp.asarray(valid)))

        if self.compression.mode != "none":
            payload_step = self._payload()
            fold = self._fold(bool(bias_correct))
            acc = jax.tree.map(lambda w: jnp.zeros(w.shape, jnp.float32),
                               params)
            for j, (idx, valid) in enumerate(gathers):
                m_r = jnp.asarray(mask)[idx] * valid
                with tracer.span("local_train", backend=self.name,
                                 region=j):
                    payload = payload_step(params, xb[idx], yb[idx],
                                           wb[idx], eta)
                    if tracer.active:
                        jax.block_until_ready(payload)
                if tracer.active:
                    self._count_bytes(params, len(groups[j]))
                acc = fold(acc, params, payload, m_r, p, counts)
            with tracer.span("aggregate", backend=self.name,
                             regions=len(groups)):
                out = self._apply32(params, acc)
                if tracer.active:
                    jax.block_until_ready(out)
            return out

        step = self._chunk_step(bool(bias_correct), hetero)
        num = den = agg = None
        for j, (idx, valid) in enumerate(gathers):
            m_r = jnp.asarray(mask)[idx] * valid
            wm_r = (None if not hetero
                    else jax.tree.map(lambda m: m[idx], wmasks))
            with tracer.span("local_train", backend=self.name, region=j):
                part = step(params, xb[idx], yb[idx], wb[idx], m_r, p, eta,
                            counts, wm_r)
                if tracer.active:
                    jax.block_until_ready(part)
            if tracer.active:
                self._count_bytes(params, len(groups[j]))
            if hetero:
                n_p, d_p = part
                num = n_p if num is None else jax.tree.map(jnp.add, num, n_p)
                den = d_p if den is None else jax.tree.map(jnp.add, den, d_p)
            else:
                agg = part if agg is None else jax.tree.map(jnp.add, agg,
                                                            part)
        with tracer.span("aggregate", backend=self.name,
                         regions=len(groups)):
            out = (self._apply_hetero(params, num, den) if hetero
                   else self._apply(params, agg))
            if tracer.active:
                jax.block_until_ready(out)
        return out


def make_backend(backend=None, model=None, *, exec: ExecSpec | None = None,
                 chunk_size: int | None = None, mesh=None,
                 local_iters: int | None = None, l2: float | None = None,
                 donate: bool | None = None, compression=None,
                 agg_impl: str | None = None, lam: float | None = None,
                 max_age: int | None = None, buffer_cap: int | None = None,
                 regions: int | None = None) -> ExecutionBackend:
    """Build an :class:`ExecutionBackend` from an
    :class:`repro.fl.spec.ExecSpec` (``exec=``, or an ExecSpec as the
    first positional argument) or from the legacy kwargs — both funnel
    through :meth:`ExecSpec.resolve`, so the two call forms are
    equivalent. An :class:`ExecutionBackend` instance passes through
    unchanged.

    Legacy kwargs default to None (= the spec's value): ``backend`` names
    one of :data:`BACKENDS`; ``compression`` is a
    :mod:`repro.core.compression` spec (None | mode string |
    ``(mode, top_k)`` | :class:`CompressionConfig`) selecting the
    client->server wire format the reduction consumes; ``agg_impl``
    (``"jnp" | "pallas"``) picks the aggregation implementation — "pallas"
    routes stacked-layer folds through the fused kernels (``adel_agg`` /
    ``adel_agg_q8``, interpret mode on CPU) on the dense, temporal and
    buffered backends and on every compressed non-shard_map path;
    ``lam`` / ``max_age`` / ``buffer_cap`` are the buffered backend's
    staleness knobs. Knobs the selected backend would silently ignore
    warn (or raise, under ``REPRO_EXEC_STRICT=1``) via
    :meth:`ExecSpec.validate`.
    """
    if isinstance(backend, ExecutionBackend):
        return backend
    if isinstance(backend, ExecSpec):
        exec, backend = (backend if exec is None else exec), None
    legacy = dict(backend=backend, chunk_size=chunk_size, mesh=mesh,
                  local_iters=local_iters, l2=l2, donate=donate,
                  compression=compression, agg_impl=agg_impl, lam=lam,
                  max_age=max_age, buffer_cap=buffer_cap, regions=regions)
    has_legacy = any(v is not None for v in legacy.values())
    # a complete ExecSpec was validated by the resolve() that built it;
    # re-validate only when legacy kwargs modify it
    spec = ExecSpec.resolve(exec, validate=has_legacy or exec is None,
                            **legacy)
    if isinstance(spec.backend, ExecutionBackend):
        return spec.backend
    kw = spec.backend_kwargs()
    if spec.backend == "dense":
        return DenseBackend(model, **kw)
    if spec.backend == "chunked":
        return ChunkedBackend(model, chunk_size=spec.chunk_size, **kw)
    if spec.backend == "shard_map":
        return ShardMapBackend(model, mesh=spec.mesh, **kw)
    if spec.backend == "temporal":
        return TemporalBackend(model, **kw)
    if spec.backend == "buffered":
        return BufferedBackend(model, lam=spec.lam, max_age=spec.max_age,
                               buffer_cap=spec.buffer_cap, **kw)
    if spec.backend == "hierarchical":
        return HierarchicalBackend(model, regions=spec.regions,
                                   chunk_size=spec.chunk_size, **kw)
    raise ValueError(f"unknown backend {spec.backend!r}; known: {BACKENDS}")
