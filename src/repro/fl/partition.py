"""Client data partitioning: IID and Dirichlet non-IID (Hsu et al. 2019),
as used in the paper's CIFAR-10 experiments (alpha = 0.5)."""
from __future__ import annotations

import numpy as np

__all__ = ["iid_partition", "dirichlet_partition", "stack_clients"]


def iid_partition(n: int, U: int, seed: int = 0) -> list[np.ndarray]:
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    return [np.sort(s) for s in np.array_split(perm, U)]


def dirichlet_partition(labels: np.ndarray, U: int, alpha: float = 0.5,
                        seed: int = 0, min_per_client: int = 2) -> list[np.ndarray]:
    """Sample client-specific label proportions from Dir(alpha) and allocate."""
    rng = np.random.default_rng(seed)
    labels = np.asarray(labels)
    classes = np.unique(labels)
    client_idx: list[list[int]] = [[] for _ in range(U)]
    for c in classes:
        idx = rng.permutation(np.where(labels == c)[0])
        props = rng.dirichlet(np.full(U, alpha))
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for u, part in enumerate(np.split(idx, cuts)):
            client_idx[u].extend(part.tolist())
    # guarantee a minimum per client (move from the largest donors)
    for u in range(U):
        while len(client_idx[u]) < min_per_client:
            donor = int(np.argmax([len(ci) for ci in client_idx]))
            client_idx[u].append(client_idx[donor].pop())
    return [np.sort(np.asarray(ci, dtype=np.int64)) for ci in client_idx]


def stack_clients(x: np.ndarray, y: np.ndarray, parts: list[np.ndarray],
                  n_pad: int | None = None
                  ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pad per-client shards to a common N and stack to (U, N, ...).

    Padding repeats each client's own data (valid counts returned separately),
    so with-replacement sampling never sees foreign samples. ``n_pad``
    overrides the common N (callers needing a jit-stable shape across
    varying client subsets, e.g. the fleet engine, pass a fixed one).
    """
    U = len(parts)
    n_max = max(len(p) for p in parts) if n_pad is None else int(n_pad)
    xs = np.zeros((U, n_max) + x.shape[1:], x.dtype)
    ys = np.zeros((U, n_max), y.dtype)
    counts = np.zeros((U,), np.int32)
    for u, p in enumerate(parts):
        k = len(p)
        reps = int(np.ceil(n_max / k))
        tiled = np.tile(p, reps)[:n_max]
        xs[u] = x[tiled]
        ys[u] = y[tiled]
        counts[u] = min(k, n_max)   # never index past an n_pad truncation
    return xs, ys, counts
