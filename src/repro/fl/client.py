"""Client-side local training for ADEL-FL and baselines.

A client receives the global model, runs E local SGD iterations on its
minibatch (E=1 reproduces the paper's main setting, Eq. 2; E in {3,5} is the
robustness study of Section IV-C), and returns its *model delta*
delta_u = w_t - w_u. For E=1, delta_u = eta * grad, so layer-wise
aggregation of deltas is exactly the gradient-space form of Eq. (5).

Depth-limited backprop is simulated by masking deltas per layer at
aggregation time (the layers a straggler never reached keep delta 0), which
is mathematically identical to truncating the backward pass — the paper's
own simulation does the same on a GPU.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

PyTree = Any


def local_update(loss_fn: Callable, params: PyTree, x: jnp.ndarray,
                 y: jnp.ndarray, sample_w: jnp.ndarray, eta: jnp.ndarray,
                 *, local_iters: int = 1, l2: float = 0.0) -> PyTree:
    """Run E local SGD iterations; return delta_u = w_t - w_u (pytree).

    loss_fn(params, x, y, sample_w) -> scalar weighted empirical risk.
    """

    def step_loss(p):
        base = loss_fn(p, x, y, sample_w)
        if l2 > 0.0:
            base = base + 0.5 * l2 * sum(
                jnp.sum(leaf.astype(jnp.float32) ** 2) for leaf in jax.tree.leaves(p))
        return base

    def body(p, _):
        g = jax.grad(step_loss)(p)
        p = jax.tree.map(lambda w, gg: w - eta * gg, p, g)
        return p, None

    p_final, _ = jax.lax.scan(body, params, None, length=local_iters)
    return jax.tree.map(lambda w0, w1: w0 - w1, params, p_final)


def batched_client_deltas(loss_fn: Callable, params: PyTree, xb: jnp.ndarray,
                          yb: jnp.ndarray, wb: jnp.ndarray, eta: jnp.ndarray,
                          *, local_iters: int = 1, l2: float = 0.0) -> PyTree:
    """vmap ``local_update`` over the leading client axis of (xb, yb, wb)."""
    fn = functools.partial(local_update, loss_fn, local_iters=local_iters, l2=l2)
    return jax.vmap(fn, in_axes=(None, 0, 0, 0, None))(params, xb, yb, wb, eta)


def sample_client_batches(key: jax.Array, data_x: jnp.ndarray,
                          data_y: jnp.ndarray, n_per_client: jnp.ndarray,
                          batch_sizes: jnp.ndarray, s_max: int):
    """Uniform with-replacement minibatch per client, padded to s_max.

    data_x: (U, N, ...), data_y: (U, N); n_per_client: (U,) valid counts;
    batch_sizes: (U,) this round's S_t^u. Returns (xb, yb, wb) where
    wb[u, i] = 1/S_u for i < S_u else 0 (so a weighted sum is the batch mean).

    NOTE: the draw is tied to the (U, s_max) shape by jax's counter-based
    PRNG, so callers that pad the client axis (``repro.fl.runtime``) must
    sample at the UNPADDED width and zero-pad xb/yb/wb afterwards — never
    sample at a backend-dependent padded width.
    """
    U, N = data_y.shape
    idx = jax.random.randint(key, (U, s_max), 0, 2 ** 30)
    idx = idx % jnp.maximum(n_per_client[:, None], 1)
    xb = jnp.take_along_axis(
        data_x, idx.reshape(idx.shape + (1,) * (data_x.ndim - 2)), axis=1)
    yb = jnp.take_along_axis(data_y, idx, axis=1)
    S = jnp.clip(batch_sizes, 1, s_max).astype(jnp.float32)
    wb = (jnp.arange(s_max)[None, :] < S[:, None]).astype(jnp.float32) / S[:, None]
    return xb, yb, wb
