"""Unified federated round runtime — ONE round loop for every workload.

One :class:`RoundRuntime` owns everything a federated round loop needs,
independent of the task being trained (image classification, synthetic
fleet workloads, big-arch LM token streams) and of how the cohort's
compute is executed:

* per-round policy planning through the ``view=`` kwarg of
  :meth:`repro.core.baselines.Policy.round`,
* cohort stacking / padding to jit-stable fixed shapes (padded rows carry
  an all-zero mask, batch size 1, and zero data, so they contribute 0),
* ``s_max`` probing (:func:`probe_s_max`, vectorized over the FULL
  schedule so non-monotone re-planned deadline tails can never plan a
  batch the executor would silently clip),
* HeteroFL width-mask derivation (cached per distinct width-ratio vector),
* the simulated wall-clock under Requirements R1 (max R rounds) and
  R2 (total time <= T_max),
* online re-planning (:mod:`repro.core.replan`), including crediting the
  un-spent deadline of skipped empty rounds back to the next re-solve,
* eval cadence and the :class:`History` record.

The three axes of variation are all pluggable:

* WHAT is trained is a task adapter (:mod:`repro.fl.tasks`): a
  :class:`~repro.fl.tasks.Task` bundles a :class:`ModelAPI`, a data source
  (classification ``(U, n, feat)`` arrays or LM token rows
  ``(U, n, seq+1)`` with shifted-label batching inside the model's loss),
  and eval metrics (classification accuracy vs token accuracy /
  perplexity) — supplied to :meth:`RoundRuntime.run` as ``eval_fn``.
* HOW a round executes is an :class:`repro.fl.backends.ExecutionBackend`
  (``dense`` / ``chunked`` / ``shard_map`` / ``temporal`` / ``buffered`` /
  ``hierarchical``), selected through one
  :class:`repro.fl.spec.ExecSpec`; all of them donate
  the incoming ``params`` buffers to the round step. Stateful backends
  (the buffered semi-async carry buffer) additionally receive a
  :class:`RoundContext` each round — the simulated clock span plus the
  straggler-model rates — so in-flight work can cross round boundaries.
* WHERE the clients come from is a cohort source:
  :class:`StaticCohortSource` replays one pre-stacked population every
  round (``repro.fl.server.run_federated`` and the LM driver
  ``repro.launch.train``), while the fleet engine's source samples
  availability + cohort per round (``repro.fleet.engine.run_fleet``).

Per-round observers (checkpointing, logging) hook in via the ``on_round``
callback of :meth:`RoundRuntime.run`. Policies, width masks, availability
models, and re-planning are therefore written once and work under every
backend and every task.

Observability flows through the single ``tracer=`` hook
(:mod:`repro.obs`, default :data:`repro.obs.NULL_TRACER` — zero overhead,
bit-identical trajectories): the runtime emits nestable phase spans
(``cohort`` / ``replan`` / ``plan`` / ``stack`` / ``eval`` /
``checkpoint``; the execution backends add ``local_train`` /
``aggregate``), typed counters (padded-vs-real batch elements, skipped
rounds, replan solver steps), and one clock-model ledger event per
executed round (:func:`repro.obs.ledger.round_record`: planned deadline
vs simulated clock vs measured wall time vs the exponential model's
predicted straggler depths). ``verbose=True`` renders from the same
records via :mod:`repro.obs.format`, so printed and recorded numbers
cannot drift apart; the aggregate lands in ``History.telemetry``.

Pipelined execution (``ExecSpec.pipeline``) — the round loop runs in one
of two modes, with bit-identical trajectories:

* ``"serial"`` (default): plan round t, execute round t, repeat — the
  classic loop.
* ``"prefetch"``: a one-round-lookahead driver. Every host-only phase of
  round t+1 — ``cohort`` sampling, the replan trigger + re-solve, the
  PRNG key splits, the policy ``plan``, the ``T_max`` stop check, and the
  minibatch ``stack`` (host numpy + H2D transfer) — runs on a worker
  thread while round t's ``backend.run_round`` is in flight on the
  device. Those phases read only sequential host state (source RNG,
  schedule, replanner, the PLANNED clock — ``plan.elapsed`` is known
  before execution), never round t's device results, which is what makes
  the speculation exact. The two things that do read live state stay on
  the main thread at consume time: HeteroFL width masks (need current
  ``params``) and all telemetry emission (the worker only collects
  timings; see :meth:`repro.obs.Tracer.span_record`). The prefetcher
  keeps at most two rounds of stacked ``(xb, yb, wb, mask)`` buffers
  alive (the in-flight round's and the prefetched round's — a double
  buffer whose slots are dropped right after dispatch), and it never
  touches ``params``, so round-step donation stays safe. After a skipped
  round or a replan event the next round is planned inline (serial
  fallback) — those rounds change the planning state the speculation
  would have had to guess. Prefetch mode also AOT-warms the backend's
  round step and the eval step (``backend.warm_up`` + one dummy eval)
  before dispatching round 0, so first-round trace/compile cost moves
  out of the measured round loop. Eval becomes non-blocking in BOTH
  modes: ``eval_fn`` returns device scalars that sit in a pending ring
  and are materialized to ``History`` floats only at report boundaries
  (a rendered eval record, a replan event, an ``on_round`` hook, end of
  run) — the only hard syncs left are the ones an active tracer
  explicitly inserts. New counters: ``h2d_bytes`` (stacked bytes shipped
  per round), ``prefetch_overlap_s`` (worker planning time hidden behind
  device execution), ``dispatch_wait_s`` (main-thread stalls on the
  prefetch future), ``warm_up_s``.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.baselines import Policy, RoundPlan
from repro.core.replan import Replanner, make_replan
from repro.fl.backends import make_backend
from repro.fl.client import sample_client_batches
from repro.fl.spec import ExecSpec

PyTree = Any

__all__ = ["ModelAPI", "History", "Cohort", "StaticCohortSource",
           "RoundContext", "RoundRuntime", "probe_s_max", "evaluate",
           "eval_metrics"]


@dataclasses.dataclass
class ModelAPI:
    """Minimal model interface consumed by the FL runtime."""

    init: Callable[[jax.Array], PyTree]
    loss: Callable[[PyTree, jnp.ndarray, jnp.ndarray, jnp.ndarray], jnp.ndarray]
    predict: Callable[[PyTree, jnp.ndarray], jnp.ndarray]
    layer_ids: Callable[[PyTree], PyTree]
    L: int
    name: str = "model"
    # HeteroFL support: width_masks(params, ratios (U,)) -> pytree with leading U axis
    width_masks: Optional[Callable[[PyTree, np.ndarray], PyTree]] = None


@dataclasses.dataclass
class History:
    times: list = dataclasses.field(default_factory=list)
    rounds: list = dataclasses.field(default_factory=list)
    accuracy: list = dataclasses.field(default_factory=list)
    deadlines: list = dataclasses.field(default_factory=list)
    train_loss: list = dataclasses.field(default_factory=list)
    # fleet runs only: reachable-device count per executed round
    available: list = dataclasses.field(default_factory=list)
    # online re-planning only: one record per mid-run re-solve
    # (round, reachable N, re-estimated U, new T tail, new m, ...)
    replans: list = dataclasses.field(default_factory=list)
    method: str = ""
    # tracer-enabled runs only: the run's telemetry summary — per-phase
    # wall totals, counter totals, the per-round clock-model ledger, and
    # its drift statistics (repro.obs.Tracer.summary)
    telemetry: dict = dataclasses.field(default_factory=dict)

    def as_dict(self) -> dict:
        """JSON-round-trippable dict (``json.dump``-able as-is).

        ``replans`` entries are converted through their own ``as_dict``
        when they are :class:`repro.core.replan.ReplanEvent` dataclasses —
        ``dataclasses.asdict`` recursion would also swallow jax/numpy
        leaves elsewhere and silently deep-copies every list, so the
        conversion is explicit and shallow.
        """
        d = {f.name: getattr(self, f.name) for f in dataclasses.fields(self)}
        d["replans"] = [r.as_dict() if hasattr(r, "as_dict") else r
                        for r in self.replans]
        return d


def _jit_predict(model: ModelAPI):
    """One jit wrapper per ModelAPI instance, reused across every eval call
    (a fresh ``jax.jit(model.predict)`` per call would retrace each time)."""
    fn = getattr(model, "_predict_jit", None)
    if fn is None:
        fn = jax.jit(model.predict)
        model._predict_jit = fn
    return fn


def evaluate(model: ModelAPI, params: PyTree, x: jnp.ndarray, y: jnp.ndarray,
             batch: int = 512) -> jnp.ndarray:
    """Full test-set accuracy as a DEVICE scalar (no host sync).

    Per-batch correct counts accumulate on-device, so every predict batch
    dispatches asynchronously and the caller decides when (if ever) to
    block — the round runtime defers the conversion to report boundaries.
    ``float()`` the result for a Python number.
    """
    n = x.shape[0]
    predict = _jit_predict(model)
    correct = jnp.int32(0)
    for i in range(0, n, batch):
        logits = predict(params, x[i:i + batch])
        correct = correct + (jnp.argmax(logits, -1) == y[i:i + batch]).sum()
    return correct / float(n)


def eval_metrics(model: ModelAPI, params: PyTree, test_x: jnp.ndarray,
                 test_y: jnp.ndarray, *, loss_samples: int = 256
                 ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(accuracy over the full test set, mean loss over a fixed head),
    both device scalars — no host sync (see :func:`evaluate`)."""
    acc = evaluate(model, params, test_x, test_y)
    n = min(loss_samples, int(test_y.shape[0]))
    loss = model.loss(params, test_x[:n], test_y[:n],
                      jnp.full((n,), 1.0 / n, jnp.float32))
    return acc, loss


def probe_s_max(policy: Policy, rounds: int, *, view=None) -> int:
    """Largest batch size the policy can plan over the FULL horizon, so
    per-client minibatches can be padded to one fixed width.

    Schedule-driven policies (ADEL) are probed with one vectorized
    ``Schedule.batch_sizes`` evaluation over EVERY round's deadline — a
    re-planned schedule need not be monotone, so probing only the
    endpoints could under-estimate a mid-schedule peak and silently clip
    batches. Fixed-deadline policies plan the same batch every round and
    keep the cheap endpoint probe.
    """
    cfg = policy._resolve(view) if hasattr(policy, "_resolve") else view
    sch = getattr(policy, "schedule", None)
    R = max(int(rounds), 1)
    if sch is not None and cfg is not None and len(np.asarray(sch.T)) >= R:
        S = sch.batch_sizes(cfg)[:R]            # (R, U), all rounds at once
        return int(S.max())
    probe = [policy.round(jax.random.PRNGKey(0), t, view=view)
             for t in (0, max(rounds - 1, 0))]
    return int(max(float(jnp.max(pl.batch_sizes)) for pl in probe))


@dataclasses.dataclass
class Cohort:
    """One round's stacked client data, as produced by a cohort source.

    ``x``: (U_act, n_pad, ...) inputs — trailing dims are task-defined
    (``(feat...)`` for classification, ``(seq+1,)`` token rows for LM);
    ``y``: (U_act, n_pad) labels (all-zero for tasks whose loss derives
    labels from ``x``), ``counts``: (U_act,) valid samples per client.
    ``view`` is the per-round AnalysisConfig the policy should plan against
    (None keeps the policy's static config), ``available`` the
    reachable-device count (None outside fleet runs). ``regions`` is the
    per-client edge-region id (``(U_act,)`` int32, from the population
    draw) consumed by the hierarchical backend; None lets that backend
    fall back to a contiguous split.
    """

    x: Any
    y: Any
    counts: Any
    view: Any = None
    available: Optional[int] = None
    regions: Optional[np.ndarray] = None

    @property
    def size(self) -> int:
        return int(self.x.shape[0])


class StaticCohortSource:
    """The same pre-stacked client population every round (the classic
    ``run_federated`` setting: cohort == population, no churn)."""

    def __init__(self, client_x, client_y, n_per_client):
        self._cohort = Cohort(x=client_x, y=client_y, counts=n_per_client)

    @property
    def cohort_size(self) -> int:
        return self._cohort.size

    def round_cohort(self, t: int) -> Cohort:
        return self._cohort


@dataclasses.dataclass
class RoundContext:
    """The round's view of the simulated clock and straggler model, for
    backends that carry work across round boundaries (the buffered
    semi-async backend).

    ``sim_start``/``sim_end`` are the round's simulated-clock span
    (``sim_end - sim_start`` = planned deadline); ``lam`` the realized
    Poisson rates of the straggler draw; ``layer_s`` the mean per-layer
    backprop time ``S_u / P_u`` (the exponential clock); ``B`` the comm/
    setup overhead — all over the ACTIVE (unpadded) cohort rows, so a
    backend can model when a straggler's in-flight work lands.
    """

    t: int
    sim_start: float
    sim_end: float
    U_act: int
    lam: np.ndarray        # (U_act,)
    layer_s: np.ndarray    # (U_act,)
    B: np.ndarray          # (U_act,)
    # per-client edge-region ids from the cohort draw (hierarchical
    # backend); None -> the backend's contiguous fallback split
    regions: Any = None


def _round_context(t: int, elapsed: float, plan: RoundPlan, view_cfg,
                   U_act: int, regions=None) -> RoundContext:
    """Recover the straggler-model rates the plan was drawn under. Both
    policy families price a client's layer clock as Exp(S_u / P_u) with
    deadline ``plan.elapsed`` (B1-B3), so ``lam = P/S * max(T - B, 0)``
    reproduces the rate regardless of how S was chosen (B3 scaling or the
    baselines' fixed batch)."""
    T_d = float(plan.elapsed)
    P = np.asarray(view_cfg.P, np.float32)[:U_act]
    B = np.asarray(view_cfg.B_eff, np.float32)[:U_act]
    S = np.asarray(plan.batch_sizes, np.float32)
    S = (np.full(U_act, float(S), np.float32) if S.ndim == 0
         else S[:U_act])
    lam = P / np.maximum(S, 1.0) * np.maximum(T_d - B, 0.0)
    layer_s = S / np.maximum(P, 1e-9)
    return RoundContext(t=t, sim_start=float(elapsed),
                        sim_end=float(elapsed) + T_d, U_act=int(U_act),
                        lam=lam, layer_s=layer_s, B=B, regions=regions)


@dataclasses.dataclass
class _Prepared:
    """One planned round from the sequential host planner — everything the
    dispatch step needs, plus the worker-side telemetry to re-emit on the
    main thread. ``kind`` is ``"round"`` (executable), ``"skip"`` (empty
    cohort), or ``"stop"`` (the T_max budget check failed). The stacked
    device arrays are one slot of the prefetch double buffer;
    :meth:`release` drops them right after dispatch."""

    t: int
    kind: str
    spans: list                        # [(phase, t0, dur_s, attrs)]
    t_start: float = 0.0               # worker wall window, for the
    t_end: float = 0.0                 # prefetch_overlap_s counter
    replan: Optional[tuple] = None     # (event record dict, solver steps)
    cohort: Any = None
    plan: Any = None
    xb: Any = None
    yb: Any = None
    wb: Any = None
    mask: Any = None
    U_act: int = 0
    view_cfg: Any = None
    ctx: Any = None
    h2d_bytes: int = 0

    def release(self) -> None:
        self.cohort = self.xb = self.yb = self.wb = self.mask = None


class RoundRuntime:
    """The single federated round loop, parameterized by execution backend.

    HOW rounds execute is an :class:`repro.fl.spec.ExecSpec` (``exec=``):
    backend selection (``dense | chunked | shard_map | temporal |
    buffered``), its knobs (``chunk_size`` / ``mesh`` / staleness), the
    local-update shape (``local_iters`` / ``l2``), params-buffer donation,
    and the client->server wire format + aggregation implementation
    (``compression`` / ``agg_impl``). The individual kwargs remain as
    deprecated aliases — both forms funnel through
    :meth:`ExecSpec.resolve`, so trajectories are bit-identical either
    way. ``backend`` may also be an
    :class:`repro.fl.backends.ExecutionBackend` instance (passed through).

    ``tracer`` (:class:`repro.obs.Tracer`) enables structured telemetry —
    phase spans, counters, and the per-round clock-model ledger (including
    the buffered backend's ``carried_in``/``carried_out`` columns) — for
    the runtime AND the backend; the default :data:`repro.obs.NULL_TRACER`
    records nothing and perturbs nothing.

    ``ExecSpec.pipeline`` selects the round-driver mode (``"serial"`` |
    ``"prefetch"``): see the module docstring for the execution timeline.
    Both modes produce bit-identical trajectories.
    """

    def __init__(self, model: ModelAPI, policy: Policy, *,
                 exec: Optional[ExecSpec] = None, backend=None,
                 chunk_size: Optional[int] = None, mesh=None,
                 local_iters: Optional[int] = None,
                 l2: Optional[float] = None, donate: Optional[bool] = None,
                 compression=None, agg_impl: Optional[str] = None,
                 tracer=None):
        self.model = model
        self.policy = policy
        self.tracer = tracer if tracer is not None else obs.NULL_TRACER
        self.backend = make_backend(backend, model, exec=exec,
                                    chunk_size=chunk_size, mesh=mesh,
                                    local_iters=local_iters, l2=l2,
                                    donate=donate, compression=compression,
                                    agg_impl=agg_impl)
        self.backend.set_tracer(self.tracer)
        self.pipeline = exec.pipeline if exec is not None else "serial"
        self._wmask_cache: dict[bytes, PyTree] = {}

    # ------------------------------------------------------------------
    def _width_masks(self, params: PyTree, ratios, U_pad: int) -> PyTree:
        if self.model.width_masks is None:
            raise ValueError("model does not support HeteroFL width masks")
        r = np.asarray(ratios, np.float32)
        if r.shape[0] < U_pad:
            # padded clients pose as full-width; their mask row is zero, so
            # they never touch the overlap mean
            r = np.concatenate([r, np.ones(U_pad - r.shape[0], np.float32)])
        key = r.tobytes()
        if key not in self._wmask_cache:
            # fleet cohorts re-derive ratios every round, so bound the cache
            # (each entry is a cohort-sized mask pytree) LRU-style
            while len(self._wmask_cache) >= 8:
                self._wmask_cache.pop(next(iter(self._wmask_cache)))
            self._wmask_cache[key] = self.model.width_masks(params, r)
        return self._wmask_cache[key]

    def _prepare(self, cohort: Cohort, plan: RoundPlan, k_batch, s_max: int,
                 U_pad: int):
        """Draw the per-client minibatches, then pad the cohort axis to the
        backend's fixed width.

        Sampling always happens at the UNPADDED cohort width: jax's
        counter-based PRNG ties the draw to the array shape, so sampling at
        a backend-dependent padded width would give every backend different
        minibatches. Padded rows get all-zero batches, weights, and mask —
        their aggregation coefficients are 0, so they contribute nothing.
        """
        U_act = cohort.size
        xb, yb, wb = sample_client_batches(
            k_batch, jnp.asarray(cohort.x), jnp.asarray(cohort.y),
            jnp.asarray(cohort.counts), jnp.asarray(plan.batch_sizes), s_max)
        mask = jnp.asarray(plan.mask, jnp.float32)
        if U_pad != U_act:
            pad = U_pad - U_act
            zrow = lambda a: jnp.concatenate(
                [a, jnp.zeros((pad,) + a.shape[1:], a.dtype)])
            xb, yb, wb, mask = zrow(xb), zrow(yb), zrow(wb), zrow(mask)
        return xb, yb, wb, mask, U_act

    # ------------------------------------------------------------------
    def run(self, source, *, rounds: int, T_max: float, eta, s_max: int,
            key: jax.Array, test_x=None, test_y=None, eval_every: int = 1,
            verbose: bool = False, method: str = "",
            replan=None, eval_fn: Optional[Callable] = None,
            on_round: Optional[Callable] = None) -> tuple[PyTree, History]:
        """Run up to ``rounds`` rounds, stopping when the simulated clock
        exceeds ``T_max``; returns ``(params, History)``.

        ``eval_fn`` (``params -> (metric, loss)``) supplies the task's eval
        metrics — token accuracy / token CE for LM tasks
        (:meth:`repro.fl.tasks.Task.eval_fn`); when None the classification
        default :func:`eval_metrics` runs over ``test_x``/``test_y``.

        ``on_round`` (``(t, params, hist) -> None``) is called after every
        EXECUTED round — the checkpointing hook of the LM training driver.

        ``replan`` (None | trigger name | :class:`repro.core.replan.
        ReplanConfig`) enables online re-solving of the remaining-horizon
        Problem 2 when churn shifts the reachable population: the trigger is
        evaluated before each round against the cohort source's reachable
        count, the re-solve warm-starts from the incumbent schedule tail,
        and each event is appended to ``History.replans``. A round whose
        cohort is empty (``round_cohort`` returns None) never starts; its
        planned deadline is credited back to the replanner
        (:meth:`repro.core.replan.Replanner.note_skip`), which zeroes the
        stranded historical deadline and forces a re-solve at the next
        executed round so the un-spent budget is re-allocated immediately.
        Sources may expose ``replan_view(t, budget_left, eta_tail)`` to
        re-estimate the population view (the fleet source does); without it
        the policy's static config is restricted to the remaining horizon.

        Execution timeline: all host-only planning phases of a round
        (``cohort`` / ``replan`` / key splits / ``plan`` / T_max check /
        ``stack``) run through one sequential planner. Under
        ``pipeline="serial"`` it is called inline each round; under
        ``"prefetch"`` round t+1's call overlaps round t's device step on
        a worker thread, with a serial-fallback round after every skip or
        replan event (module docstring has the full picture). Eval results
        stay device scalars in a pending ring and are materialized at
        report boundaries, before every ``on_round`` call, on replan
        events, and at the end of the run — ``History`` always holds plain
        floats by the time ``run`` returns.
        """
        model, policy, backend = self.model, self.policy, self.backend
        if getattr(policy, "name", "") == "heterofl" and \
                model.width_masks is None:
            raise ValueError("model does not support HeteroFL width masks")
        if eval_fn is None:
            if test_x is None:
                raise ValueError("run() needs either eval_fn or "
                                 "test_x/test_y")
            eval_fn = lambda p: eval_metrics(model, p, test_x, test_y)
        replan = make_replan(replan)
        replanner = (Replanner(replan, policy, rounds, eta, s_max=s_max,
                               rate_max=getattr(source, "plan_rate_max",
                                                None))
                     if replan is not None and replan.active else None)
        key, k_init = jax.random.split(key)
        params = model.init(k_init)
        U_pad = backend.cohort_pad(source.cohort_size)
        backend.reset_state()        # stateful backends: fresh carry buffer
        needs_ctx = bool(getattr(backend, "needs_ctx", False))
        prefetch = self.pipeline == "prefetch"

        tracer = self.tracer
        hist = History(method=method or policy.name)
        elapsed = 0.0
        wall_start = obs.now()

        # -- the sequential host planner ---------------------------------
        # Everything here reads host state only (source RNG, replanner,
        # schedule, the PLANNED clock — `plan.elapsed` is known before the
        # round executes), never a device result, so the prefetcher can run
        # it one round ahead and the trajectory stays bit-identical. The
        # planner's clock mirrors `elapsed` exactly: every planned "round"
        # is later executed, skips spend nothing, and a "stop" halts both.
        # Telemetry is collected locally and re-emitted at consume time on
        # the main thread (tracer sinks are not thread-safe).
        plan_key = key
        planned_elapsed = 0.0

        def plan_round(t: int) -> _Prepared:
            nonlocal plan_key, planned_elapsed
            spans: list = []
            t_start = t0 = obs.now()
            cohort = source.round_cohort(t)
            spans.append(("cohort", t0, obs.now() - t0, {}))
            if cohort is None:
                # nobody reachable: the round never starts and spends
                # nothing — credit its planned deadline back so the next
                # re-solve re-allocates it instead of stranding it
                if replanner is not None:
                    replanner.note_skip(t)
                return _Prepared(t=t, kind="skip", spans=spans,
                                 t_start=t_start, t_end=obs.now())
            rep = None
            if replanner is not None:
                reachable = (cohort.available if cohort.available is not None
                             else source.cohort_size)
                if replanner.should_replan(t, reachable):
                    view = None
                    budget_left = max(T_max - planned_elapsed, 1e-6)
                    view_fn = getattr(source, "replan_view", None)
                    if view_fn is not None:
                        view = view_fn(t, budget_left, eta[t:rounds])
                    t0 = obs.now()
                    ev = replanner.replan(t, budget_left, reachable, view)
                    spans.append(("replan", t0, obs.now() - t0,
                                  {"reachable": int(reachable)}))
                    rep = (ev.as_dict(), int(ev.steps))
            plan_key, k_round, k_batch = jax.random.split(plan_key, 3)
            t0 = obs.now()
            plan: RoundPlan = policy.round(k_round, t, view=cohort.view)
            spans.append(("plan", t0, obs.now() - t0, {}))
            if planned_elapsed + plan.elapsed > T_max * (1 + 1e-6):
                return _Prepared(t=t, kind="stop", spans=spans, replan=rep,
                                 t_start=t_start, t_end=obs.now())
            t0 = obs.now()
            xb, yb, wb, mask, U_act = self._prepare(cohort, plan, k_batch,
                                                    s_max, U_pad)
            spans.append(("stack", t0, obs.now() - t0, {}))
            view_cfg = (cohort.view if cohort.view is not None
                        else policy.cfg)
            ctx = (_round_context(t, planned_elapsed, plan, view_cfg,
                                  U_act, regions=cohort.regions)
                   if needs_ctx else None)
            planned_elapsed += plan.elapsed
            return _Prepared(t=t, kind="round", spans=spans, replan=rep,
                             t_start=t_start, t_end=obs.now(),
                             cohort=cohort, plan=plan, xb=xb, yb=yb, wb=wb,
                             mask=mask, U_act=U_act, view_cfg=view_cfg,
                             ctx=ctx,
                             h2d_bytes=obs.tree_bytes((xb, yb, wb, mask)))

        pool = (concurrent.futures.ThreadPoolExecutor(
                    max_workers=1, thread_name_prefix="prefetch")
                if prefetch else None)
        pending: Optional[concurrent.futures.Future] = None
        pending_evals: list[int] = []    # History rows awaiting float()
        warmed = False
        dispatch_t0: Optional[float] = None

        def drain_evals() -> None:
            """Materialize deferred eval device scalars into History (the
            conversion is the sync point; everything before it is free)."""
            for i in pending_evals:
                hist.accuracy[i] = float(hist.accuracy[i])
                hist.train_loss[i] = float(hist.train_loss[i])
            pending_evals.clear()

        try:
            for t in range(rounds):
                tracer.set_round(t + 1)
                wall_round0 = obs.now() if tracer.active else 0.0
                if pending is not None:
                    t0 = obs.now()
                    prep: _Prepared = pending.result()
                    pending = None
                    if tracer.active:
                        t_res = obs.now()
                        tracer.count("dispatch_wait_s",
                                     round(t_res - t0, 6))
                        tracer.count("prefetch_rounds", 1)
                        if dispatch_t0 is not None:
                            # worker wall time hidden behind the device
                            # dispatch window of the previous round
                            lo = max(prep.t_start, dispatch_t0)
                            hi = min(prep.t_end, t_res)
                            if hi > lo:
                                tracer.count("prefetch_overlap_s",
                                             round(hi - lo, 6))
                else:
                    prep = plan_round(t)
                for name, s0, dur, attrs in prep.spans:
                    tracer.span_record(name, s0, dur, **attrs)
                if prep.replan is not None:
                    rec, steps = prep.replan
                    hist.replans.append(rec)
                    tracer.event("replan", **rec)
                    tracer.count("replan_solver_steps", steps)
                    drain_evals()      # replan events report live state
                    if verbose:
                        print(obs.format_replan(hist.method, rec))
                if prep.kind == "skip":
                    tracer.count("rounds_skipped", 1)
                    continue
                if prep.kind == "stop":
                    break
                plan, U_act, view_cfg = prep.plan, prep.U_act, prep.view_cfg
                wmasks = None
                if plan.width_ratios is not None:
                    # HeteroFL masks read the LIVE params tree — the one
                    # stack input the planner cannot speculate on
                    t0 = obs.now()
                    wmasks = self._width_masks(params, plan.width_ratios,
                                               U_pad)
                    tracer.span_record("stack", t0, obs.now() - t0,
                                       part="wmasks")
                if prefetch and prep.replan is None and t + 1 < rounds:
                    # overlap round t+1's host phases with round t's device
                    # step; a replan round forces the next plan inline (and
                    # a skip `continue`s before this point)
                    pending = pool.submit(plan_round, t + 1)
                if prefetch and not warmed:
                    # AOT warm-up: compile+execute the round step and the
                    # eval step on dummies before round 0 dispatches, so
                    # trace cost never lands inside a measured round
                    t0 = obs.now()
                    backend.warm_up(params, prep.xb, prep.yb, prep.wb,
                                    prep.mask, plan.p, jnp.float32(eta[t]),
                                    bias_correct=bool(plan.bias_correct),
                                    wmasks=wmasks, ctx=prep.ctx)
                    dummy = jax.tree.map(
                        lambda a: jnp.zeros(jnp.shape(a),
                                            jnp.result_type(a)), params)
                    jax.block_until_ready(eval_fn(dummy))
                    dur = obs.now() - t0
                    tracer.span_record("warm_up", t0, dur)
                    tracer.count("warm_up_s", round(dur, 6))
                    warmed = True
                available = prep.cohort.available
                dispatch_t0 = obs.now()
                params = backend.run_round(
                    params, prep.xb, prep.yb, prep.wb, prep.mask, plan.p,
                    jnp.float32(eta[t]),
                    bias_correct=bool(plan.bias_correct),
                    wmasks=wmasks, ctx=prep.ctx)
                tracer.count("h2d_bytes", prep.h2d_bytes)
                prep.release()       # free this round's double-buffer slot
                elapsed += plan.elapsed
                if tracer.active:
                    # the clock-model ledger row: planned deadline vs
                    # simulated clock vs measured wall vs the model's view
                    jax.block_until_ready(params)
                    wall_now = obs.now()
                    tracer.count(
                        "batch_elements_real",
                        int(np.minimum(np.asarray(plan.batch_sizes,
                                                  np.float64)[:U_act],
                                       float(s_max)).sum()))
                    tracer.count("batch_elements_padded", U_pad * s_max)
                    tracer.gauge("cohort_size", U_act)
                    tracer.event("round", **obs.round_record(
                        t=t, plan=plan, cfg=view_cfg, L=model.L,
                        U_act=U_act, U_pad=U_pad, s_max=s_max,
                        sim_total=elapsed,
                        wall_round_s=wall_now - wall_round0,
                        wall_total_s=wall_now - wall_start,
                        available=available,
                        carry=getattr(backend, "last_carry", None) or None,
                        regions=getattr(backend, "last_regions",
                                        None) or None))
                if (t % eval_every == 0) or (t == rounds - 1):
                    with tracer.span("eval"):
                        acc, loss = eval_fn(params)
                        if tracer.active:
                            # explicit telemetry sync: the span should
                            # measure eval compute, not async dispatch
                            jax.block_until_ready((acc, loss))
                    hist.times.append(elapsed)
                    hist.rounds.append(t + 1)
                    hist.accuracy.append(acc)
                    hist.deadlines.append(float(plan.elapsed))
                    hist.train_loss.append(loss)
                    if available is not None:
                        hist.available.append(int(available))
                    pending_evals.append(len(hist.accuracy) - 1)
                    if tracer.active or verbose:
                        # ONE record for the sink and the console, rendered
                        # from exactly what History keeps — only this
                        # report boundary pays the float() conversion
                        drain_evals()
                        rec = {"round": t + 1, "available": available,
                               "cohort": U_act, "sim_total": elapsed,
                               "T_deadline": float(plan.elapsed),
                               "acc": hist.accuracy[-1],
                               "loss": hist.train_loss[-1]}
                        tracer.event("eval", **rec)
                        if verbose:
                            print(obs.format_eval(hist.method, rec))
                if on_round is not None:
                    drain_evals()    # hooks read materialized History
                    with tracer.span("checkpoint"):
                        on_round(t, params, hist)
        finally:
            if pool is not None:
                if pending is not None:
                    pending.cancel()
                pool.shutdown(wait=True)
        drain_evals()
        tracer.set_round(None)
        if tracer.active:
            hist.telemetry = tracer.summary()
        return params, hist
