"""Static-population front-end over the unified round runtime.

``run_federated`` is a thin wrapper: it probes ``s_max``, wraps the
pre-stacked client arrays in a :class:`repro.fl.runtime.StaticCohortSource`
(cohort == population, ``view=None`` every round), and hands the loop to
:class:`repro.fl.runtime.RoundRuntime`, which owns policy planning, cohort
padding, the simulated R1/R2 clock, eval cadence, and the
:class:`repro.fl.runtime.History` record. HOW each round executes is an
interchangeable :mod:`repro.fl.backends` backend — ``dense`` (one vmap over
the cohort, the default here), ``chunked`` (sequential software psum), or
``shard_map`` (a real client mesh axis with ``jax.lax.psum``) — all
numerically equivalent up to float summation order.

``ModelAPI`` / ``History`` / ``evaluate`` / ``eval_metrics`` are defined in
:mod:`repro.fl.runtime` and re-exported here for compatibility.
"""
from __future__ import annotations

import numpy as np

from repro.core.baselines import Policy
from repro.core.types import AnalysisConfig
from repro.fl.runtime import (History, ModelAPI, RoundRuntime,
                              StaticCohortSource, eval_metrics, evaluate,
                              probe_s_max)

__all__ = ["ModelAPI", "History", "evaluate", "eval_metrics",
           "run_federated"]

PyTree = object


def run_federated(model: ModelAPI, policy: Policy, cfg: AnalysisConfig,
                  client_x, client_y, n_per_client, test_x, test_y, *, key,
                  eta: np.ndarray | None = None, local_iters: int = 1,
                  l2: float = 0.0, s_max: int | None = None,
                  eval_every: int = 1, verbose: bool = False,
                  backend="dense", chunk_size: int = 16,
                  mesh=None, replan=None, donate: bool = True,
                  compression=None, agg_impl: str = "jnp",
                  eval_fn=None, on_round=None,
                  tracer=None) -> tuple[PyTree, History]:
    """Run up to R rounds, stopping when the simulated clock exceeds T_max.

    ``replan`` (None | trigger name | ``repro.core.replan.ReplanConfig``)
    enables online remaining-horizon re-solves of Problem 2 (ADEL policy
    only); the static population never drifts, so ``every-k`` is the only
    trigger that fires here — it re-solves the tail against the same
    constants with the exact un-spent budget.

    ``eval_fn`` / ``on_round`` / ``donate`` are forwarded to
    :meth:`repro.fl.runtime.RoundRuntime.run` — task-specific eval metrics
    (:mod:`repro.fl.tasks`), a per-round observer (checkpointing), and
    params-buffer donation in the backend round steps. ``tracer``
    (:class:`repro.obs.Tracer`) enables structured telemetry — phase
    spans, counters, and the clock-model ledger in ``History.telemetry``.
    """
    eta = cfg.eta if eta is None else np.asarray(eta, np.float32)
    if s_max is None:
        # largest batch any client can be assigned under the policy
        s_max = max(min(probe_s_max(policy, cfg.R),
                        int(client_y.shape[1])), 2)
    runtime = RoundRuntime(model, policy, backend=backend,
                           chunk_size=chunk_size, mesh=mesh,
                           local_iters=local_iters, l2=l2, donate=donate,
                           compression=compression, agg_impl=agg_impl,
                           tracer=tracer)
    source = StaticCohortSource(client_x, client_y, n_per_client)
    return runtime.run(source, rounds=cfg.R, T_max=cfg.T_max, eta=eta,
                       s_max=s_max, key=key, test_x=test_x, test_y=test_y,
                       eval_every=eval_every, verbose=verbose,
                       method=policy.name, replan=replan, eval_fn=eval_fn,
                       on_round=on_round)
