"""Static-population front-end over the unified round runtime.

``run_federated`` is a thin wrapper: it probes ``s_max``, wraps the
pre-stacked client arrays in a :class:`repro.fl.runtime.StaticCohortSource`
(cohort == population, ``view=None`` every round), and hands the loop to
:class:`repro.fl.runtime.RoundRuntime`, which owns policy planning, cohort
padding, the simulated R1/R2 clock, eval cadence, and the
:class:`repro.fl.runtime.History` record. HOW each round executes is an
:class:`repro.fl.spec.ExecSpec` (``exec=``) selecting an interchangeable
:mod:`repro.fl.backends` backend — ``dense`` (one vmap over the cohort,
the default here), ``chunked`` (sequential software psum), ``shard_map``
(a real client mesh axis with ``jax.lax.psum``), ``temporal``
(grad-accumulation scan), or ``buffered`` (semi-async delayed gradients)
— the synchronous ones numerically equivalent up to float summation
order.

``ModelAPI`` / ``History`` / ``evaluate`` / ``eval_metrics`` are defined in
:mod:`repro.fl.runtime` and re-exported here for compatibility.
"""
from __future__ import annotations

import numpy as np

from repro.core.baselines import Policy
from repro.core.types import AnalysisConfig
from repro.fl.runtime import (History, ModelAPI, RoundRuntime,
                              StaticCohortSource, eval_metrics, evaluate,
                              probe_s_max)
from repro.fl.spec import ExecSpec

__all__ = ["ModelAPI", "History", "evaluate", "eval_metrics",
           "run_federated"]

PyTree = object


def run_federated(model: ModelAPI, policy: Policy, cfg: AnalysisConfig,
                  client_x, client_y, n_per_client, test_x, test_y, *, key,
                  eta: np.ndarray | None = None,
                  exec: ExecSpec | None = None,
                  local_iters: int | None = None,
                  l2: float | None = None, s_max: int | None = None,
                  eval_every: int = 1, verbose: bool = False,
                  backend=None, chunk_size: int | None = None,
                  mesh=None, replan=None, donate: bool | None = None,
                  compression=None, agg_impl: str | None = None,
                  eval_fn=None, on_round=None,
                  tracer=None) -> tuple[PyTree, History]:
    """Run up to R rounds, stopping when the simulated clock exceeds T_max.

    HOW rounds execute is one :class:`repro.fl.spec.ExecSpec` (``exec=``):
    backend choice (dense is the default here), ``chunk_size`` / ``mesh``
    / staleness knobs, ``local_iters`` / ``l2``, params donation, and
    ``compression`` / ``agg_impl``. The individual kwargs are deprecated
    aliases kept for compatibility; both forms resolve through
    :meth:`ExecSpec.resolve` (inapplicable-knob combinations warn, or
    raise under ``REPRO_EXEC_STRICT=1``) and produce bit-identical
    trajectories.

    ``replan`` (None | trigger name | ``repro.core.replan.ReplanConfig``)
    enables online remaining-horizon re-solves of Problem 2 (ADEL policy
    only); the static population never drifts, so ``every-k`` is the only
    trigger that fires here — it re-solves the tail against the same
    constants with the exact un-spent budget.

    ``eval_fn`` / ``on_round`` are forwarded to
    :meth:`repro.fl.runtime.RoundRuntime.run` — task-specific eval metrics
    (:mod:`repro.fl.tasks`) and a per-round observer (checkpointing).
    ``tracer`` (:class:`repro.obs.Tracer`) enables structured telemetry —
    phase spans, counters, and the clock-model ledger in
    ``History.telemetry`` (including the buffered backend's
    ``carried_in`` / ``carried_out`` columns).
    """
    eta = cfg.eta if eta is None else np.asarray(eta, np.float32)
    if s_max is None:
        # largest batch any client can be assigned under the policy
        s_max = max(min(probe_s_max(policy, cfg.R),
                        int(client_y.shape[1])), 2)
    runtime = RoundRuntime(model, policy, exec=exec, backend=backend,
                           chunk_size=chunk_size, mesh=mesh,
                           local_iters=local_iters, l2=l2, donate=donate,
                           compression=compression, agg_impl=agg_impl,
                           tracer=tracer)
    source = StaticCohortSource(client_x, client_y, n_per_client)
    return runtime.run(source, rounds=cfg.R, T_max=cfg.T_max, eta=eta,
                       s_max=s_max, key=key, test_x=test_x, test_y=test_y,
                       eval_every=eval_every, verbose=verbose,
                       method=policy.name, replan=replan, eval_fn=eval_fn,
                       on_round=on_round)
