"""Server-side federated round loop with simulated wall-clock accounting.

``run_federated`` drives any :class:`repro.core.baselines.Policy` (ADEL-FL or
a baseline) against a ModelAPI + per-client dataset, under the paper's
Requirements R1 (max R rounds) and R2 (total time <= T_max).
"""
from __future__ import annotations

import dataclasses
import functools
import time as _time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import aggregate_grads
from repro.core.baselines import Policy, RoundPlan
from repro.core.types import AnalysisConfig
from repro.fl.client import batched_client_deltas, sample_client_batches

PyTree = Any


@dataclasses.dataclass
class ModelAPI:
    """Minimal model interface consumed by the FL runtime."""

    init: Callable[[jax.Array], PyTree]
    loss: Callable[[PyTree, jnp.ndarray, jnp.ndarray, jnp.ndarray], jnp.ndarray]
    predict: Callable[[PyTree, jnp.ndarray], jnp.ndarray]
    layer_ids: Callable[[PyTree], PyTree]
    L: int
    name: str = "model"
    # HeteroFL support: width_masks(params, ratios (U,)) -> pytree with leading U axis
    width_masks: Optional[Callable[[PyTree, np.ndarray], PyTree]] = None


@dataclasses.dataclass
class History:
    times: list = dataclasses.field(default_factory=list)
    rounds: list = dataclasses.field(default_factory=list)
    accuracy: list = dataclasses.field(default_factory=list)
    deadlines: list = dataclasses.field(default_factory=list)
    train_loss: list = dataclasses.field(default_factory=list)
    # fleet runs only: reachable-device count per executed round
    available: list = dataclasses.field(default_factory=list)
    method: str = ""

    def as_dict(self):
        return dataclasses.asdict(self)


def make_round_step(model: ModelAPI, *, local_iters: int, l2: float,
                    bias_correct: bool, hetero: bool = False):
    """One jitted federated round: client deltas -> aggregation -> update.

    Shared by :func:`run_federated` and ``repro.fleet.engine`` (the fleet
    engine uses it directly whenever the whole cohort fits in one chunk).
    """

    @functools.partial(jax.jit, static_argnames=())
    def step(params, xb, yb, wb, mask, p, eta, wmasks):
        deltas = batched_client_deltas(model.loss, params, xb, yb, wb, eta,
                                       local_iters=local_iters, l2=l2)
        ids = model.layer_ids(params)
        if hetero:
            # HeteroFL: per-entry overlap mean over participating clients.
            part = mask[:, 0]  # all-or-nothing rows
            def agg_leaf(d, wm):
                w = part.reshape((-1,) + (1,) * (d.ndim - 1)) * wm
                num = (w * d).sum(0)
                den = jnp.maximum(w.sum(0), 1.0)
                return num / den
            agg = jax.tree.map(agg_leaf, deltas, wmasks)
        else:
            agg = aggregate_grads(deltas, ids, mask, p, bias_correct=bias_correct)
        new_params = jax.tree.map(lambda w, d: w - d, params, agg)
        return new_params

    return step


def evaluate(model: ModelAPI, params: PyTree, x: jnp.ndarray, y: jnp.ndarray,
             batch: int = 512) -> float:
    n = x.shape[0]
    correct = 0
    predict = jax.jit(model.predict)
    for i in range(0, n, batch):
        logits = predict(params, x[i:i + batch])
        correct += int((jnp.argmax(logits, -1) == y[i:i + batch]).sum())
    return correct / n


def eval_metrics(model: ModelAPI, params: PyTree, test_x: jnp.ndarray,
                 test_y: jnp.ndarray, *, loss_samples: int = 256
                 ) -> tuple[float, float]:
    """(accuracy over the full test set, mean loss over a fixed head)."""
    acc = evaluate(model, params, test_x, test_y)
    n = min(loss_samples, int(test_y.shape[0]))
    loss = float(model.loss(params, test_x[:n], test_y[:n],
                            jnp.full((n,), 1.0 / n, jnp.float32)))
    return acc, loss


def run_federated(model: ModelAPI, policy: Policy, cfg: AnalysisConfig,
                  client_x: jnp.ndarray, client_y: jnp.ndarray,
                  n_per_client: jnp.ndarray, test_x: jnp.ndarray,
                  test_y: jnp.ndarray, *, key: jax.Array,
                  eta: np.ndarray | None = None, local_iters: int = 1,
                  l2: float = 0.0, s_max: int | None = None,
                  eval_every: int = 1, verbose: bool = False) -> tuple[PyTree, History]:
    """Run up to R rounds, stopping when the simulated clock exceeds T_max."""
    eta = cfg.eta if eta is None else np.asarray(eta, np.float32)
    key, k_init = jax.random.split(key)
    params = model.init(k_init)

    if s_max is None:
        # largest batch any client can be assigned under the policy
        probe = [policy.round(jax.random.PRNGKey(0), t) for t in (0, cfg.R - 1)]
        s_max = int(max(float(jnp.max(pl.batch_sizes)) for pl in probe))
        s_max = max(min(s_max, int(client_y.shape[1])), 2)

    hetero = getattr(policy, "name", "") == "heterofl"
    wmasks = None
    if hetero:
        if model.width_masks is None:
            raise ValueError("model does not support HeteroFL width masks")
        wmasks = model.width_masks(params, policy.ratios)

    step_cache: dict[bool, Callable] = {}

    hist = History(method=policy.name)
    elapsed = 0.0
    for t in range(cfg.R):
        key, k_round, k_batch = jax.random.split(key, 3)
        plan: RoundPlan = policy.round(k_round, t)
        if elapsed + plan.elapsed > cfg.T_max * (1 + 1e-6):
            break
        xb, yb, wb = sample_client_batches(
            k_batch, client_x, client_y, n_per_client, plan.batch_sizes, s_max)
        bc = bool(plan.bias_correct)
        if bc not in step_cache:
            step_cache[bc] = make_round_step(
                model, local_iters=local_iters, l2=l2, bias_correct=bc,
                hetero=hetero)
        params = step_cache[bc](params, xb, yb, wb, plan.mask, plan.p,
                                jnp.float32(eta[t]), wmasks)
        elapsed += plan.elapsed
        if (t % eval_every == 0) or (t == cfg.R - 1):
            acc, loss = eval_metrics(model, params, test_x, test_y)
            hist.times.append(elapsed)
            hist.rounds.append(t + 1)
            hist.accuracy.append(acc)
            hist.deadlines.append(float(plan.elapsed))
            hist.train_loss.append(loss)
            if verbose:
                print(f"[{policy.name}] round {t+1:3d} time {elapsed:9.2f} "
                      f"deadline {plan.elapsed:7.3f} acc {acc:.4f}")
    return params, hist
