"""llava-next-34b [vlm] — anyres tiling [hf:llava-hf/llava-v1.6-mistral-7b-hf].

Language backbone only; the SigLIP/ViT tower + projector is a stub —
``input_specs`` supplies precomputed patch embeddings (anyres: base tile +
4 sub-tiles of 576 patches = 2880 image tokens) of shape (B, 2880, d_model).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b", family="vlm",
    L=60, d_model=7168, n_heads=56, n_kv=8, d_head=128,
    d_ff=20480, vocab=64000,
    rope_mode="full", rope_theta=5_000_000.0,
    frontend="vision", n_frontend_tokens=2880,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)
