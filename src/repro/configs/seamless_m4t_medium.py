"""seamless-m4t-medium [audio] — enc-dec, multimodal [arXiv:2308.11596].

Transformer backbone only: 12 encoder + 12 decoder blocks (L=12 per stack),
d_model=1024, 16 heads, d_ff=4096. The conformer speech frontend
(mel-spectrogram + conv feature extractor) is a stub — ``input_specs``
supplies precomputed frame embeddings (B, n_frames, d_model) to the encoder.
ADEL mask layers: encoder blocks are the deepest (ids 0..11), decoder blocks
ids 12..23 (backprop reaches the decoder first).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium", family="audio",
    L=12, enc_layers=12, d_model=1024, n_heads=16, n_kv=16, d_head=64,
    d_ff=4096, vocab=256206,
    rope_mode="none",                      # sinusoidal/learned in the original
    frontend="audio", n_frontend_tokens=1024,
    source="arXiv:2308.11596",
)
