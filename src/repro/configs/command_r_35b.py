"""command-r-35b [dense] — GQA, no-bias [hf:CohereForAI/c4ai-command-r-v01]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="command-r-35b", family="dense",
    L=40, d_model=8192, n_heads=64, n_kv=8, d_head=128,
    d_ff=22528, vocab=256000,
    rope_mode="full", rope_theta=8_000_000.0, tie_embeddings=True,
    source="hf:CohereForAI/c4ai-command-r-v01",
)
