"""deepseek-v2-lite-16b [moe] — MLA kv_lora=512 [arXiv:2405.04434].

MLA latent attention (compressed KV cache), MoE FFN with 2 shared + 64
routed experts, top-6 routing, per-expert hidden 1408. The assignment
bracket mentions "160 routed" which matches full V2; we follow the explicit
``MoE 64e top-6`` field of the config line (V2-Lite).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b", family="moe",
    L=27, d_model=2048, n_heads=16, n_kv=16,
    d_ff=1408, vocab=102400,
    attention="mla", kv_lora=512,
    mla_nope_dim=128, mla_rope_dim=64, mla_v_dim=128,
    n_experts=64, top_k=6, n_shared=2, moe_d_ff=1408,
    rope_mode="full", rope_theta=10_000.0,
    source="arXiv:2405.04434",
)
