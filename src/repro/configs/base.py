"""ArchConfig: one dataclass describing every supported architecture family,
plus the four assigned input shapes.

Families: dense (GQA transformer), ssm (Mamba2 SSD), moe (GQA/MLA + MoE FFN),
vlm / audio (transformer backbone with a stubbed modality frontend — patch /
frame embeddings arrive precomputed, per the assignment carve-out), hybrid
(parallel attention + SSM heads, Hymba-style), and enc-dec (audio).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.compression import CompressionConfig
from repro.core.replan import ReplanConfig
from repro.fl.spec import ExecSpec

__all__ = ["ArchConfig", "CompressionConfig", "ExecSpec", "FleetConfig",
           "InputShape", "INPUT_SHAPES", "ReplanConfig", "pad_vocab"]


def pad_vocab(v: int, multiple: int = 512) -> int:
    """Pad vocab so the embedding/lm-head shard evenly on the model axis."""
    return ((v + multiple - 1) // multiple) * multiple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | ssm | moe | vlm | audio | hybrid
    L: int                         # decoder blocks
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    source: str = ""               # citation bracket from the assignment
    # attention
    attention: str = "gqa"         # gqa | mla | none
    d_head: int = 0                # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_mode: str = "full"        # full | half (ChatGLM 2d) | none
    rope_theta: float = 10000.0
    window: int = 0                # sliding-window size (0 = full attention)
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared: int = 0
    moe_d_ff: int = 0              # per-expert hidden dim (0 -> d_ff)
    dense_residual: bool = False   # Arctic: dense FFN parallel to MoE
    capacity_factor: float = 1.25  # GShard capacity (decode is dropless)
    # MLA (DeepSeek-V2)
    kv_lora: int = 0
    mla_nope_dim: int = 128
    mla_rope_dim: int = 64
    mla_v_dim: int = 128
    # SSM (Mamba2 SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 64            # SSD chunk length Q (a §Perf lever)
    # hybrid (Hymba): parallel attention + SSM heads in every block
    # enc-dec (audio)
    enc_layers: int = 0
    # modality frontend stub
    frontend: str = "none"         # none | vision | audio
    n_frontend_tokens: int = 0     # patch/frame embeddings per sample
    # misc
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    # Lowering form: False -> lax.scan over stacked blocks (O(1) HLO size —
    # the production/training form). True -> fully unrolled layers, used by
    # the dry-run cost path because XLA's HloCostAnalysis counts a while-loop
    # body ONCE regardless of trip count, which silently undercounts FLOPs/
    # bytes/collective-bytes by ~L for scanned layers (verified empirically).
    unroll_layers: bool = False

    # ------------------------------------------------------------------
    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        return pad_vocab(self.vocab)

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def n_blocks_total(self) -> int:
        """Total mask layers L for ADEL (encoder blocks count as deeper layers)."""
        return self.L + self.enc_layers

    @property
    def has_attention(self) -> bool:
        return self.attention != "none"

    @property
    def has_ssm(self) -> bool:
        return self.ssm_state > 0

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def sub_quadratic(self) -> bool:
        """True iff the arch can serve long_500k (no dense full-attn KV cache)."""
        return (not self.has_attention) or self.window > 0

    def param_count(self) -> int:
        """Approximate parameter count (embeddings included once if tied)."""
        D, V = self.d_model, self.padded_vocab
        n = V * D * (1 if self.tie_embeddings else 2)
        per = 2 * D  # norms
        if self.has_attention:
            if self.attention == "mla":
                dn, dr, dv, c = (self.mla_nope_dim, self.mla_rope_dim,
                                 self.mla_v_dim, self.kv_lora)
                per += D * self.n_heads * (dn + dr) + D * c + D * dr
                per += c * self.n_heads * (dn + dv) + self.n_heads * dv * D
            else:
                hd = self.head_dim
                per += D * self.n_heads * hd + 2 * D * self.n_kv * hd
                per += self.n_heads * hd * D
        if self.has_ssm:
            di, N = self.d_inner, self.ssm_state
            per += D * (2 * di + 2 * N + self.ssm_heads)  # in_proj (z,x,b,c,dt)
            per += self.ssm_conv * (di + 2 * N)           # conv1d
            per += di * D + 2 * self.ssm_heads            # out_proj, A_log, D skip
        if self.is_moe:
            F = self.expert_d_ff
            per += D * self.n_experts                      # router
            per += self.n_experts * 3 * D * F              # routed experts
            if self.n_shared:
                per += 3 * D * (self.n_shared * F)
            if self.dense_residual:
                per += 3 * D * self.d_ff
        elif not self.has_ssm or self.family == "hybrid":
            per += 3 * D * self.d_ff                       # SwiGLU
        n += self.L * per
        if self.enc_layers:
            hd = self.head_dim
            enc_per = (D * self.n_heads * hd + 2 * D * self.n_kv * hd
                       + self.n_heads * hd * D + 3 * D * self.d_ff + 2 * D)
            # decoder cross-attention
            n += self.L * (D * self.n_heads * hd + 2 * D * self.n_kv * hd
                           + self.n_heads * hd * D + D)
            n += self.enc_layers * enc_per
        return n

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k routed + shared experts
        + dense residual only). Used for MODEL_FLOPS = 6 N_active D."""
        if not self.is_moe:
            return self.param_count()
        full = self.param_count()
        F = self.expert_d_ff
        routed_all = self.L * self.n_experts * 3 * self.d_model * F
        routed_active = self.L * self.top_k * 3 * self.d_model * F
        return full - routed_all + routed_active

    def nonembedding_param_count(self) -> int:
        V, D = self.padded_vocab, self.d_model
        emb = V * D * (1 if self.tie_embeddings else 2)
        return self.param_count() - emb

    def reduced(self, **overrides) -> "ArchConfig":
        """Smoke-test variant: 2 layers, d_model <= 512, <= 4 experts."""
        kw = dataclasses.asdict(self)
        shrink = max(1, self.d_model // 256)
        d_red = max(128, (self.d_model // shrink) // 64 * 64)
        kw.update(
            L=2,
            enc_layers=min(self.enc_layers, 2),
            d_model=d_red,
            n_heads=max(self.n_heads // shrink, 1),
            n_kv=max(self.n_kv // shrink, 1),
            d_head=min(self.head_dim, 64),
            d_ff=max(self.d_ff // shrink, 8),
            vocab=min(self.vocab, 512),
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            moe_d_ff=max(self.expert_d_ff // shrink, 8) if self.is_moe else 0,
            kv_lora=min(self.kv_lora, 64),
            mla_nope_dim=min(self.mla_nope_dim, 32),
            mla_rope_dim=min(self.mla_rope_dim, 16),
            mla_v_dim=min(self.mla_v_dim, 32),
            ssm_state=min(self.ssm_state, 16),
            ssm_head_dim=min(self.ssm_head_dim, 16),
            n_frontend_tokens=min(self.n_frontend_tokens, 16),
            window=min(self.window, 64) if self.window else 0,
            dtype="float32",
        )
        kw.update(overrides)
        # keep n_heads a multiple of n_kv
        kw["n_heads"] = max(kw["n_heads"] - kw["n_heads"] % kw["n_kv"], kw["n_kv"])
        return ArchConfig(**kw)


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Fleet-simulation block consumed by ``repro.fleet``.

    Describes a heterogeneous device population and the per-round cohort
    drawn from it (see ``repro/fleet/__init__.py`` for the subsystem docs).
    ``availability_kwargs`` is a tuple of (key, value) pairs so the config
    stays hashable/frozen; use :meth:`availability_dict` to consume it.
    """

    preset: str = "uniform"        # profiles.PRESETS key (ignored w/ trace)
    size: int = 500                # number of simulated devices
    trace_path: Optional[str] = None   # JSON device trace overrides preset
    # population source spec (repro.fleet.population.PopulationSpec source
    # forms: "PRESET" | "trace:PATH" | "mobiperf:PATH" |
    # "parametric:PRESET"). When set it wins over preset/trace_path; None
    # keeps legacy configs building the same MaterializedPopulation they
    # always did.
    population: Optional[str] = None
    availability: str = "always-on"    # availability.AVAILABILITY key
    availability_kwargs: tuple = ()
    # edge-region count for hierarchical two-tier aggregation (device id %
    # regions); 1 = flat single-server topology
    regions: int = 1
    cohort_size: int = 32          # U clients planned per round
    cohort_strategy: str = "uniform"   # uniform | power-of-choice | stratified
    # full execution spec (repro.fl.spec.ExecSpec). When set it is the
    # single source of truth for backend/chunk/compression/staleness; the
    # legacy backend/chunk_size/compression fields below then act as the
    # resolve() base they always were (exec wins). None keeps legacy-only
    # configs bit-identical.
    exec: Optional[ExecSpec] = None
    # execution backend (repro.fl.backends):
    # dense | chunked | shard_map | temporal | buffered
    backend: str = "chunked"
    chunk_size: int = 16           # client-shard axis chunk (chunked backend)
    # online re-planning block (repro.core.replan): trigger "never" keeps
    # the static offline schedule; "every-k" / "drift" re-solve the
    # remaining-horizon Problem 2 against the reachable population
    replan: ReplanConfig = ReplanConfig()
    # client->server wire compression (repro.core.compression): mode "none"
    # ships dense float32 deltas; "int8" / "topk8" make the compressed
    # payload what the backend's reduction consumes, and scale the
    # Problem-2 solver's per-user communication time B_u by the wire ratio
    compression: CompressionConfig = CompressionConfig()
    seed: int = 0

    def availability_dict(self) -> dict:
        return dict(self.availability_kwargs)

    def population_spec(self):
        """The config's :class:`repro.fleet.population.PopulationSpec`:
        ``population`` when set, else the legacy preset/trace fields mapped
        onto the spec's source forms (imported lazily — configs must stay
        importable without the fleet subsystem)."""
        from repro.fleet.population import PopulationSpec
        source = self.population or (f"trace:{self.trace_path}"
                                     if self.trace_path else self.preset)
        return PopulationSpec(source=source, size=self.size,
                              availability=self.availability,
                              availability_kwargs=self.availability_kwargs,
                              regions=self.regions, seed=self.seed)

    def exec_spec(self) -> ExecSpec:
        """The effective execution spec: ``exec`` when set, else an
        :class:`ExecSpec` assembled from the legacy backend / chunk_size /
        compression fields (identical resolution either way)."""
        if self.exec is not None:
            return self.exec
        return ExecSpec(backend=self.backend, chunk_size=self.chunk_size,
                        compression=self.compression)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
