"""qwen1.5-4b [dense] — QKV bias [hf:Qwen/Qwen1.5-0.5B]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-4b", family="dense",
    L=40, d_model=2560, n_heads=20, n_kv=20, d_head=128,
    d_ff=6912, vocab=151936, qkv_bias=True,
    rope_mode="full", rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen1.5-0.5B",
)
