"""hymba-1.5b [hybrid] — parallel attn+mamba heads [arXiv:2411.13676].

Every block runs GQA attention (sliding-window 1024, hd=64) and a Mamba2 SSD
path in parallel on the same input; the two normalized outputs are averaged
(the paper's learned per-head fusion is simplified to a mean — see DESIGN.md
§Arch-applicability). Sub-quadratic (SWA + SSM state): long_500k runs.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b", family="hybrid",
    L=32, d_model=1600, n_heads=25, n_kv=5, d_head=64,
    d_ff=5504, vocab=32001,
    window=1024,
    ssm_state=16, ssm_head_dim=50, ssm_expand=2, ssm_conv=4,
    rope_mode="full", rope_theta=10_000.0,
    source="arXiv:2411.13676",
)
