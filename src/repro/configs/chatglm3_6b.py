"""chatglm3-6b [dense] — RoPE 2d (half-rotary), GQA kv=2 [arXiv:2406.12793]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="chatglm3-6b", family="dense",
    L=28, d_model=4096, n_heads=32, n_kv=2, d_head=128,
    d_ff=13696, vocab=65024, qkv_bias=True,
    rope_mode="half", rope_theta=10_000.0,
    source="arXiv:2406.12793",
)
