"""yi-6b [dense] — llama-arch GQA [arXiv:2403.04652]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="yi-6b", family="dense",
    L=32, d_model=4096, n_heads=32, n_kv=4, d_head=128,
    d_ff=11008, vocab=64000,
    rope_mode="full", rope_theta=5_000_000.0,
    source="arXiv:2403.04652",
)
