"""mamba2-370m [ssm] — SSD (state-space duality) [arXiv:2405.21060].

Attention-free: 48 SSD blocks, d_model=1024, d_inner=2048, state N=128,
head dim P=64 (32 value heads). Sub-quadratic: long_500k runs.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-370m", family="ssm",
    L=48, d_model=1024, n_heads=16, n_kv=16, d_ff=0, vocab=50280,
    attention="none", rope_mode="none",
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_conv=4,
    tie_embeddings=True,
    source="arXiv:2405.21060",
)
