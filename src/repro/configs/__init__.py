"""Architecture registry: the 10 assigned architectures + aliases.

``get_config(name)`` accepts the assignment id (e.g. "qwen1.5-4b").
"""
from __future__ import annotations

from .base import (INPUT_SHAPES, ArchConfig, CompressionConfig, FleetConfig,
                   InputShape)

from .qwen1_5_4b import CONFIG as _qwen
from .mamba2_370m import CONFIG as _mamba2
from .llava_next_34b import CONFIG as _llava
from .deepseek_v2_lite_16b import CONFIG as _dsv2
from .chatglm3_6b import CONFIG as _chatglm
from .seamless_m4t_medium import CONFIG as _seamless
from .arctic_480b import CONFIG as _arctic
from .yi_6b import CONFIG as _yi
from .hymba_1_5b import CONFIG as _hymba
from .command_r_35b import CONFIG as _commandr

ARCHS: dict[str, ArchConfig] = {c.name: c for c in [
    _qwen, _mamba2, _llava, _dsv2, _chatglm,
    _seamless, _arctic, _yi, _hymba, _commandr,
]}

__all__ = ["ARCHS", "INPUT_SHAPES", "ArchConfig", "CompressionConfig",
           "FleetConfig", "InputShape", "get_config", "get_shape"]


def get_config(name: str) -> ArchConfig:
    key = name.replace("_", "-")
    if key in ARCHS:
        return ARCHS[key]
    raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")


def get_shape(name: str) -> InputShape:
    if name in INPUT_SHAPES:
        return INPUT_SHAPES[name]
    raise KeyError(f"unknown shape {name!r}; known: {sorted(INPUT_SHAPES)}")
