"""arctic-480b [moe] — 128 experts top-2 + dense residual
[hf:Snowflake/snowflake-arctic-base].

Dense-MoE hybrid: every block has a dense SwiGLU FFN (d_ff=4864) in residual
parallel with a 128-expert top-2 MoE (per-expert hidden 4864).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="arctic-480b", family="moe",
    L=35, d_model=7168, n_heads=56, n_kv=8, d_head=128,
    d_ff=4864, vocab=32000,
    n_experts=128, top_k=2, moe_d_ff=4864, dense_residual=True,
    rope_mode="full", rope_theta=10_000.0,
    source="hf:Snowflake/snowflake-arctic-base",
)
