"""Generic layered LM backbone covering all assigned architecture families.

One :class:`~repro.configs.base.ArchConfig` selects among:

* dense / vlm  — pre-norm GQA transformer (optional QKV bias, full/half RoPE,
  optional sliding window), SwiGLU FFN.
* moe          — GQA or MLA attention + token-choice top-k MoE FFN
  (optional shared experts, optional Arctic-style dense residual FFN).
* ssm          — Mamba2 SSD blocks (attention-free).
* hybrid       — parallel attention + SSD heads per block (Hymba).
* audio        — encoder-decoder; the encoder consumes stubbed frame
  embeddings, the decoder adds cross-attention.

The L blocks are stored STACKED (leading axis L) and driven by
``jax.lax.scan`` — HLO size is O(1) in depth and ADEL-FL's per-layer
truncation masks become a single broadcast multiply over the stacked axis
(see ``layer_ids``). Forward computation is cast to ``cfg.dtype``
(bf16 on TPU) with float32 softmax/norms; parameters stay in their stored
dtype (f32 for training, bf16 for serving).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.attention import (decode_attention, gqa_attention,
                                    mla_decode, mla_prefill, rope)
from repro.models.moe import moe_ffn
from repro.models.ssm import ssd_chunked, ssd_decode_step

PyTree = Any

__all__ = ["init_params", "forward", "loss_fn", "init_cache", "prefill",
           "decode_step", "layer_ids", "param_specs", "cache_specs",
           "count_params", "Cache"]


# ---------------------------------------------------------------------------
# small helpers
# ---------------------------------------------------------------------------

def _rms_norm(x: jnp.ndarray, g: jnp.ndarray, eps: float) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * g.astype(x.dtype)


def _dense(key, shape, scale=None, dtype=jnp.float32):
    scale = scale if scale is not None else 1.0 / np.sqrt(shape[-2])
    return (scale * jax.random.normal(key, shape)).astype(dtype)


def _swiglu(h, p, compute_dtype):
    wg, wu, wd = (p["wg"].astype(compute_dtype), p["wu"].astype(compute_dtype),
                  p["wd"].astype(compute_dtype))
    return (jax.nn.silu(h @ wg) * (h @ wu)) @ wd


# ---------------------------------------------------------------------------
# parameter init (per-block dicts; stacked over L by the caller)
# ---------------------------------------------------------------------------

def _init_attn(key, cfg: ArchConfig, dtype):
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense(ks[0], (D, H * hd), dtype=dtype),
        "wk": _dense(ks[1], (D, KV * hd), dtype=dtype),
        "wv": _dense(ks[2], (D, KV * hd), dtype=dtype),
        "wo": _dense(ks[3], (H * hd, D), scale=1.0 / np.sqrt(H * hd), dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((KV * hd,), dtype)
        p["bv"] = jnp.zeros((KV * hd,), dtype)
    return p


def _init_mla(key, cfg: ArchConfig, dtype):
    D, H = cfg.d_model, cfg.n_heads
    dn, dr, dv, c = (cfg.mla_nope_dim, cfg.mla_rope_dim, cfg.mla_v_dim,
                     cfg.kv_lora)
    ks = jax.random.split(key, 6)
    return {
        "wq": _dense(ks[0], (D, H * (dn + dr)), dtype=dtype),
        "w_dkv": _dense(ks[1], (D, c), dtype=dtype),
        "w_uk": _dense(ks[2], (c, H * dn), dtype=dtype),
        "w_uv": _dense(ks[3], (c, H * dv), dtype=dtype),
        "w_kr": _dense(ks[4], (D, dr), dtype=dtype),
        "wo": _dense(ks[5], (H * dv, D), scale=1.0 / np.sqrt(H * dv), dtype=dtype),
    }


def _init_mlp(key, cfg: ArchConfig, dtype, d_ff=None):
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return {"wg": _dense(ks[0], (D, F), dtype=dtype),
            "wu": _dense(ks[1], (D, F), dtype=dtype),
            "wd": _dense(ks[2], (F, D), scale=1.0 / np.sqrt(F), dtype=dtype)}


def _init_moe(key, cfg: ArchConfig, dtype):
    D, E, F = cfg.d_model, cfg.n_experts, cfg.expert_d_ff
    ks = jax.random.split(key, 4)
    return {
        "router": _dense(ks[0], (D, E), dtype=jnp.float32),
        "wg": _dense(ks[1], (E, D, F), dtype=dtype),
        "wu": _dense(ks[2], (E, D, F), dtype=dtype),
        "wd": _dense(ks[3], (E, F, D), scale=1.0 / np.sqrt(F), dtype=dtype),
    }


def _init_ssm(key, cfg: ArchConfig, dtype):
    D, di, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    proj_out = 2 * di + 2 * N + H          # z, x, b, c, dt
    conv_ch = di + 2 * N                   # conv over (x, b, c)
    ks = jax.random.split(key, 3)
    return {
        "in_proj": _dense(ks[0], (D, proj_out), dtype=dtype),
        "conv_w": (0.1 * jax.random.normal(ks[1], (cfg.ssm_conv, conv_ch))).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.zeros((H,), jnp.float32),            # softplus -> ~0.69
        "skip_D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm_g": jnp.ones((di,), jnp.float32),
        "out_proj": _dense(ks[2], (di, D), scale=1.0 / np.sqrt(di), dtype=dtype),
    }


def _init_block(key, cfg: ArchConfig, dtype, *, encoder: bool = False):
    """One block's params; the caller vmaps this over L keys to stack."""
    ks = jax.random.split(key, 8)
    p: dict = {"norm1": jnp.ones((cfg.d_model,), jnp.float32)}
    if encoder:
        p["attn"] = _init_attn(ks[0], cfg, dtype)
        p["norm2"] = jnp.ones((cfg.d_model,), jnp.float32)
        p["mlp"] = _init_mlp(ks[1], cfg, dtype)
        return p
    if cfg.has_attention:
        p["attn"] = (_init_mla(ks[0], cfg, dtype) if cfg.attention == "mla"
                     else _init_attn(ks[0], cfg, dtype))
    if cfg.has_ssm:
        p["ssm"] = _init_ssm(ks[1], cfg, dtype)
        if cfg.family == "hybrid":
            p["fuse_na"] = jnp.ones((cfg.d_model,), jnp.float32)
            p["fuse_ns"] = jnp.ones((cfg.d_model,), jnp.float32)
    if cfg.enc_layers:                     # decoder cross-attention
        p["norm_x"] = jnp.ones((cfg.d_model,), jnp.float32)
        p["xattn"] = _init_attn(ks[2], cfg, dtype)
    if cfg.is_moe or cfg.has_attention or cfg.family == "hybrid":
        if not (cfg.family == "ssm"):
            p["norm2"] = jnp.ones((cfg.d_model,), jnp.float32)
    if cfg.is_moe:
        p["moe"] = _init_moe(ks[3], cfg, dtype)
        if cfg.n_shared:
            p["shared"] = _init_mlp(ks[4], cfg, dtype,
                                    d_ff=cfg.n_shared * cfg.expert_d_ff)
        if cfg.dense_residual:
            p["dense"] = _init_mlp(ks[5], cfg, dtype)
    elif cfg.has_attention or cfg.family == "hybrid":
        p["mlp"] = _init_mlp(ks[6], cfg, dtype)
    return p


def init_params(key: jax.Array, cfg: ArchConfig, *,
                dtype: jnp.dtype | None = None) -> PyTree:
    """Full model params. Blocks are stacked over the leading L axis."""
    dtype = dtype or jnp.float32
    k_e, k_b, k_enc, k_h = jax.random.split(key, 4)
    V, D = cfg.padded_vocab, cfg.d_model
    params = {
        "embed": _dense(k_e, (V, D), scale=0.02, dtype=dtype),
        "blocks": jax.vmap(
            lambda k: _init_block(k, cfg, dtype))(jax.random.split(k_b, cfg.L)),
        "final_norm": jnp.ones((D,), jnp.float32),
    }
    if cfg.enc_layers:
        params["enc_blocks"] = jax.vmap(
            lambda k: _init_block(k, cfg, dtype, encoder=True))(
                jax.random.split(k_enc, cfg.enc_layers))
        params["enc_norm"] = jnp.ones((D,), jnp.float32)
    if not cfg.tie_embeddings:
        params["lm_head"] = _dense(k_h, (D, V), scale=0.02, dtype=dtype)
    return params


def count_params(params: PyTree) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# block forward (full-sequence: train / prefill)
# ---------------------------------------------------------------------------

def _attn_forward(p, cfg: ArchConfig, h, positions, cdt, *, causal=True,
                  kv_override=None):
    """GQA path. h: (B, S, D). kv_override: precomputed (k, v) for cross-attn."""
    B, S, D = h.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    q = h @ p["wq"].astype(cdt)
    if "bq" in p:
        q = q + p["bq"].astype(cdt)
    q = q.reshape(B, S, H, hd)
    if kv_override is None:
        k = h @ p["wk"].astype(cdt)
        v = h @ p["wv"].astype(cdt)
        if "bk" in p:
            k = k + p["bk"].astype(cdt)
            v = v + p["bv"].astype(cdt)
        k = k.reshape(B, S, KV, hd)
        v = v.reshape(B, S, KV, hd)
        if cfg.rope_mode != "none":
            q = rope(q, positions, mode=cfg.rope_mode, theta=cfg.rope_theta)
            k = rope(k, positions, mode=cfg.rope_mode, theta=cfg.rope_theta)
    else:
        k, v = kv_override
    out = gqa_attention(q, k, v, causal=causal and kv_override is None,
                        window=cfg.window)
    return out.reshape(B, S, H * hd) @ p["wo"].astype(cdt)


def _ssm_split(p, cfg: ArchConfig, h, cdt):
    """in_proj + split. h (B,S,D) -> z (B,S,di), xbc (B,S,di+2N), dt (B,S,H)."""
    di, N, Hs = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    zxbcdt = h @ p["in_proj"].astype(cdt)
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * N]
    dt_raw = zxbcdt[..., di + di + 2 * N:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])
    return z, xbc, dt


def _ssm_forward(p, cfg: ArchConfig, h, cdt, *, chunk=None):
    chunk = chunk or cfg.ssm_chunk
    """Mamba2 SSD mixer (full sequence). h: (B, S, D) -> (B, S, D)."""
    B, S, D = h.shape
    di, N, Hs, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, xbc, dt = _ssm_split(p, cfg, h, cdt)
    # depthwise causal conv over sequence (width cfg.ssm_conv)
    w = p["conv_w"].astype(cdt)                       # (W, C)
    pad = jnp.pad(xbc, ((0, 0), (cfg.ssm_conv - 1, 0), (0, 0)))
    conv = sum(pad[:, i:i + S, :] * w[i][None, None, :]
               for i in range(cfg.ssm_conv))
    xbc = jax.nn.silu(conv + p["conv_b"].astype(cdt))
    x = xbc[..., :di].reshape(B, S, Hs, P)
    b = xbc[..., di:di + N]
    c = xbc[..., di + N:]
    A = jax.nn.softplus(p["A_log"])
    q = chunk
    if S % q:                                          # pad to a chunk multiple
        padS = q - S % q
        x = jnp.pad(x, ((0, 0), (0, padS), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, padS), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, padS), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, padS), (0, 0)))
    y, _ = ssd_chunked(x, dt, A, b, c, chunk=q)
    y = y[:, :S]
    y = y + p["skip_D"].astype(cdt)[None, None, :, None] * x[:, :S]
    y = y.reshape(B, S, di)
    y = _rms_norm(y * jax.nn.silu(z), p["norm_g"], cfg.norm_eps)
    return y @ p["out_proj"].astype(cdt)


def _ffn_forward(p, cfg: ArchConfig, h, cdt, *, dropless: bool = False):
    """Dense SwiGLU or MoE (+ shared / dense-residual). Returns (out, aux).

    ``dropless`` (decode path) sizes expert capacity so no token is dropped —
    single-token batches must match the prefill computation exactly.
    """
    if not cfg.is_moe:
        return _swiglu(h, p["mlp"], cdt), jnp.float32(0.0)
    B, S, D = h.shape
    flat = h.reshape(B * S, D)
    moe_p = {k: v.astype(cdt) if k != "router" else v
             for k, v in p["moe"].items()}
    cf = float(cfg.n_experts) if dropless else cfg.capacity_factor
    out, aux = moe_ffn(flat, moe_p, top_k=cfg.top_k, capacity_factor=cf)
    out = out.reshape(B, S, D)
    if cfg.n_shared:
        out = out + _swiglu(h, p["shared"], cdt)
    if cfg.dense_residual:
        out = out + _swiglu(h, p["dense"], cdt)
    return out, aux


def _block_forward(p, cfg: ArchConfig, h, positions, cdt, *,
                   enc_out=None, causal=True):
    """One decoder block, full sequence. Returns (h, aux)."""
    aux = jnp.float32(0.0)
    x = _rms_norm(h, p["norm1"], cfg.norm_eps)
    if cfg.family == "hybrid":
        a = _attn_forward(p["attn"], cfg, x, positions, cdt, causal=causal)
        s = _ssm_forward(p["ssm"], cfg, x, cdt)
        mix = 0.5 * (_rms_norm(a, p["fuse_na"], cfg.norm_eps)
                     + _rms_norm(s, p["fuse_ns"], cfg.norm_eps))
        h = h + mix
    elif cfg.family == "ssm":
        h = h + _ssm_forward(p["ssm"], cfg, x, cdt)
        return h, aux                                   # Mamba block has no FFN
    elif cfg.attention == "mla":
        out, _ = mla_prefill(x, {k: v.astype(cdt) for k, v in p["attn"].items()},
                             cfg, positions)
        h = h + out
    else:
        h = h + _attn_forward(p["attn"], cfg, x, positions, cdt, causal=causal)
    if enc_out is not None:                             # cross-attention
        xq = _rms_norm(h, p["norm_x"], cfg.norm_eps)
        kv = _cross_kv(p["xattn"], cfg, enc_out, cdt)
        h = h + _attn_forward(p["xattn"], cfg, xq, positions, cdt,
                              kv_override=kv)
    x2 = _rms_norm(h, p["norm2"], cfg.norm_eps)
    out, aux = _ffn_forward(p, cfg, x2, cdt)
    return h + out, aux


def _cross_kv(p, cfg: ArchConfig, enc_out, cdt):
    B, Se, D = enc_out.shape
    KV, hd = cfg.n_kv, cfg.head_dim
    k = (enc_out @ p["wk"].astype(cdt)).reshape(B, Se, KV, hd)
    v = (enc_out @ p["wv"].astype(cdt)).reshape(B, Se, KV, hd)
    if "bk" in p:
        k = k + p["bk"].astype(cdt).reshape(KV, hd)
        v = v + p["bv"].astype(cdt).reshape(KV, hd)
    return k, v


def _run_encoder(params, cfg: ArchConfig, frames, cdt):
    """Bidirectional encoder over frame embeddings (B, S_enc, D)."""
    h = frames.astype(cdt)
    positions = jnp.arange(h.shape[1])

    def body(h, p):
        h, _ = _block_forward(p, cfg, h, positions, cdt, causal=False)
        return h, None

    h, _ = jax.lax.scan(body, h, params["enc_blocks"],
                        unroll=bool(cfg.unroll_layers))
    return _rms_norm(h, params["enc_norm"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# model forward / loss (train & prefill)
# ---------------------------------------------------------------------------

def forward(params: PyTree, cfg: ArchConfig, tokens: jnp.ndarray, *,
            frontend: jnp.ndarray | None = None, remat: bool = False):
    """Logits for a full sequence.

    tokens: (B, S_text) int32. ``frontend``: (B, n_front, D) patch/frame
    embeddings — prepended for vlm, encoder input for audio.
    Returns (logits (B, S_out, V), aux) with S_out = n_front + S_text for
    vlm, S_text otherwise.
    """
    cdt = jnp.dtype(cfg.dtype)
    emb = params["embed"].astype(cdt)
    h = emb[tokens]
    enc_out = None
    if cfg.frontend == "vision" and frontend is not None:
        h = jnp.concatenate([frontend.astype(cdt), h], axis=1)
    elif cfg.frontend == "audio" and frontend is not None:
        enc_out = _run_encoder(params, cfg, frontend, cdt)
    positions = jnp.arange(h.shape[1])

    def body(carry, p):
        h, aux = carry
        h, a = _block_forward(p, cfg, h, positions, cdt, enc_out=enc_out)
        return (h, aux + a), None

    blk = jax.checkpoint(body) if remat else body
    (h, aux), _ = jax.lax.scan(blk, (h, jnp.float32(0.0)), params["blocks"],
                               unroll=bool(cfg.unroll_layers))
    h = _rms_norm(h, params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(cdt)
    logits = h @ head
    return logits, aux


def loss_fn(params: PyTree, cfg: ArchConfig, tokens: jnp.ndarray,
            labels: jnp.ndarray, *, frontend: jnp.ndarray | None = None,
            moe_aux_coef: float = 0.01, remat: bool = False) -> jnp.ndarray:
    """Mean next-token CE over the text segment (+ MoE load-balance aux)."""
    logits, aux = forward(params, cfg, tokens, frontend=frontend, remat=remat)
    if cfg.frontend == "vision" and frontend is not None:
        logits = logits[:, frontend.shape[1]:]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    loss = nll.mean()
    if cfg.is_moe:
        loss = loss + moe_aux_coef * aux / cfg.L
    return loss


# ---------------------------------------------------------------------------
# KV / SSM / conv caches + decode
# ---------------------------------------------------------------------------

class Cache(NamedTuple):
    kv: Optional[tuple] = None        # (k, v): (L, B, W_or_S, KV, hd)
    mla: Optional[tuple] = None       # (c_kv (L,B,S,c), k_pe (L,B,S,dr))
    ssm: Optional[tuple] = None       # (state (L,B,H,N,P), conv (L,B,W-1,C))
    cross: Optional[tuple] = None     # (k, v): (L, B, S_enc, KV, hd)


def init_cache(cfg: ArchConfig, batch: int, max_seq: int, *,
               dtype=None, enc_out: jnp.ndarray | None = None) -> Cache:
    dtype = dtype or jnp.dtype(cfg.dtype)
    L, KV, hd = cfg.L, cfg.n_kv, cfg.head_dim
    kv = mla = ssm = cross = None
    if cfg.has_attention:
        if cfg.attention == "mla":
            mla = (jnp.zeros((L, batch, max_seq, cfg.kv_lora), dtype),
                   jnp.zeros((L, batch, max_seq, cfg.mla_rope_dim), dtype))
        else:
            W = min(cfg.window, max_seq) if cfg.window else max_seq
            kv = (jnp.zeros((L, batch, W, KV, hd), dtype),
                  jnp.zeros((L, batch, W, KV, hd), dtype))
    if cfg.has_ssm:
        ssm = (jnp.zeros((L, batch, cfg.ssm_heads, cfg.ssm_state,
                          cfg.ssm_head_dim), jnp.float32),
               jnp.zeros((L, batch, cfg.ssm_conv - 1,
                          cfg.d_inner + 2 * cfg.ssm_state), dtype))
    return Cache(kv=kv, mla=mla, ssm=ssm, cross=cross)


def build_cross_cache(params: PyTree, cfg: ArchConfig,
                      enc_out: jnp.ndarray) -> tuple:
    """Precompute per-layer cross-attention K/V from the encoder output
    (decode then never re-projects the encoder states)."""
    cdt = jnp.dtype(cfg.dtype)

    def body(_, p):
        return None, _cross_kv(p["xattn"], cfg, enc_out, cdt)

    _, (ks, vs) = jax.lax.scan(body, None, params["blocks"],
                               unroll=bool(cfg.unroll_layers))
    return (ks, vs)


def _attn_decode(p, cfg: ArchConfig, x, pos, kv_l, cdt, cross=False,
                 n_valid=None):
    """Single-token GQA decode for one layer. x: (B, 1, D)."""
    B = x.shape[0]
    H, KV, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    q = x @ p["wq"].astype(cdt)
    if "bq" in p:
        q = q + p["bq"].astype(cdt)
    q = q.reshape(B, 1, H, hd)
    k_c, v_c = kv_l
    if cross:
        nv = k_c.shape[1] if n_valid is None else n_valid
        out = decode_attention(q, k_c, v_c, nv)
        return (out.reshape(B, 1, H * hd) @ p["wo"].astype(cdt)), kv_l
    k = x @ p["wk"].astype(cdt)
    v = x @ p["wv"].astype(cdt)
    if "bk" in p:
        k = k + p["bk"].astype(cdt)
        v = v + p["bv"].astype(cdt)
    k = k.reshape(B, 1, KV, hd)
    v = v.reshape(B, 1, KV, hd)
    if cfg.rope_mode != "none":
        pvec = jnp.full((1,), pos)
        q = rope(q, pvec, mode=cfg.rope_mode, theta=cfg.rope_theta)
        k = rope(k, pvec, mode=cfg.rope_mode, theta=cfg.rope_theta)
    W = k_c.shape[1]
    slot = (pos % W) if cfg.window else jnp.minimum(pos, W - 1)
    k_c = jax.lax.dynamic_update_slice_in_dim(k_c, k.astype(k_c.dtype), slot, 1)
    v_c = jax.lax.dynamic_update_slice_in_dim(v_c, v.astype(v_c.dtype), slot, 1)
    n_valid = jnp.minimum(pos + 1, W)
    out = decode_attention(q, k_c, v_c, n_valid)
    return (out.reshape(B, 1, H * hd) @ p["wo"].astype(cdt)), (k_c, v_c)


def _ssm_decode(p, cfg: ArchConfig, x, ssm_l, cdt):
    """Single-token SSD decode for one layer. x: (B, 1, D)."""
    B = x.shape[0]
    di, N, Hs, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    state, conv_hist = ssm_l                       # (B,H,N,P), (B,W-1,C)
    z, xbc, dt = _ssm_split(p, cfg, x, cdt)        # (B,1,·)
    seq = jnp.concatenate([conv_hist, xbc], axis=1)           # (B, W, C)
    w = p["conv_w"].astype(cdt)
    conv = jnp.einsum("bwc,wc->bc", seq, w) + p["conv_b"].astype(cdt)
    xbc1 = jax.nn.silu(conv)
    xh = xbc1[:, :di].reshape(B, Hs, P)
    b = xbc1[:, di:di + N]
    c = xbc1[:, di + N:]
    A = jax.nn.softplus(p["A_log"])
    y, state = ssd_decode_step(xh, dt[:, 0], A, b, c, state)
    y = y + p["skip_D"].astype(cdt)[None, :, None] * xh
    y = y.reshape(B, di)
    y = _rms_norm(y * jax.nn.silu(z[:, 0]), p["norm_g"], cfg.norm_eps)
    out = (y @ p["out_proj"].astype(cdt))[:, None, :]
    return out, (state, seq[:, 1:])


def decode_step(params: PyTree, cfg: ArchConfig, cache: Cache,
                token: jnp.ndarray, pos: jnp.ndarray):
    """One decode step. token: (B,) int32; pos: scalar int32 (absolute).

    Returns (logits (B, V), new_cache).
    """
    cdt = jnp.dtype(cfg.dtype)
    h = params["embed"].astype(cdt)[token][:, None, :]          # (B, 1, D)

    def body(h, xs):
        p, kv_l, mla_l, ssm_l, cross_l = xs
        x = _rms_norm(h, p["norm1"], cfg.norm_eps)
        new_kv, new_mla, new_ssm = kv_l, mla_l, ssm_l
        if cfg.family == "hybrid":
            a, new_kv = _attn_decode(p["attn"], cfg, x, pos, kv_l, cdt)
            s, new_ssm = _ssm_decode(p["ssm"], cfg, x, ssm_l, cdt)
            mix = 0.5 * (_rms_norm(a, p["fuse_na"], cfg.norm_eps)
                         + _rms_norm(s, p["fuse_ns"], cfg.norm_eps))
            h = h + mix
        elif cfg.family == "ssm":
            out, new_ssm = _ssm_decode(p["ssm"], cfg, x, ssm_l, cdt)
            return h + out, (new_kv, new_mla, new_ssm)
        elif cfg.attention == "mla":
            c_c, pe_c = mla_l
            ap = {k: v.astype(cdt) for k, v in p["attn"].items()}
            # append this token's compressed kv
            c_new = x[:, 0] @ ap["w_dkv"]
            pe_new = rope((x @ ap["w_kr"])[:, :, None, :],
                          jnp.full((1,), pos), mode="full",
                          theta=cfg.rope_theta)[:, 0, 0]
            c_c = jax.lax.dynamic_update_slice_in_dim(
                c_c, c_new[:, None].astype(c_c.dtype), pos, 1)
            pe_c = jax.lax.dynamic_update_slice_in_dim(
                pe_c, pe_new[:, None].astype(pe_c.dtype), pos, 1)
            out = mla_decode(x, ap, cfg, c_c, pe_c, pos)
            h = h + out
            new_mla = (c_c, pe_c)
        else:
            out, new_kv = _attn_decode(p["attn"], cfg, x, pos, kv_l, cdt)
            h = h + out
        if cross_l is not None:
            xq = _rms_norm(h, p["norm_x"], cfg.norm_eps)
            out, _ = _attn_decode(p["xattn"], cfg, xq, pos, cross_l, cdt,
                                  cross=True)
            h = h + out
        if "norm2" in p:
            x2 = _rms_norm(h, p["norm2"], cfg.norm_eps)
            out, _ = _ffn_forward(p, cfg, x2, cdt, dropless=True)
            h = h + out
        return h, (new_kv, new_mla, new_ssm)

    xs = (params["blocks"], cache.kv, cache.mla, cache.ssm, cache.cross)
    # scan requires every xs leaf to have leading L; None entries are passed
    # through a custom scan via masking — simplest is to substitute dummies.
    h, new_layers = _scan_with_optional(body, h, xs, cfg.L,
                                        unroll=bool(cfg.unroll_layers))
    new_kv, new_mla, new_ssm = new_layers
    h = _rms_norm(h, params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(cdt)
    logits = (h[:, 0] @ head).astype(jnp.float32)
    return logits, Cache(kv=new_kv, mla=new_mla, ssm=new_ssm,
                         cross=cache.cross)


def _scan_with_optional(body, carry, xs, L, *, unroll: bool = False):
    """lax.scan over (blocks, kv, mla, ssm, cross) where some cache groups are
    None. None groups are replaced by per-layer zero-size placeholders."""
    blocks, kv, mla, ssm, cross = xs
    dummy = jnp.zeros((L, 0), jnp.float32)

    def wrap(c, x):
        p, kv_l, mla_l, ssm_l, cross_l, _ = x
        kv_l = None if kv is None else kv_l
        mla_l = None if mla is None else mla_l
        ssm_l = None if ssm is None else ssm_l
        cross_l = None if cross is None else cross_l
        c, (nkv, nmla, nssm) = body(c, (p, kv_l, mla_l, ssm_l, cross_l))
        z = jnp.zeros((0,), jnp.float32)
        return c, (z if nkv is None else nkv, z if nmla is None else nmla,
                   z if nssm is None else nssm)

    sub = lambda g: g if g is not None else dummy
    carry, (nkv, nmla, nssm) = jax.lax.scan(
        wrap, carry, (blocks, sub(kv), sub(mla), sub(ssm), sub(cross), dummy),
        unroll=unroll)
    return carry, (None if kv is None else nkv, None if mla is None else nmla,
                   None if ssm is None else nssm)


def prefill(params: PyTree, cfg: ArchConfig, tokens: jnp.ndarray, *,
            frontend: jnp.ndarray | None = None):
    """Prefill: full-sequence forward returning last-position logits.

    (Cache materialization for the serve path is exercised by ``decode_step``;
    the prefill *compute* — the expensive part — is what prefill shapes lower.)
    """
    logits, _ = forward(params, cfg, tokens, frontend=frontend)
    return logits[:, -1]


# ---------------------------------------------------------------------------
# ADEL layer ids + sharding specs
# ---------------------------------------------------------------------------

def layer_ids(params: PyTree, cfg: ArchConfig) -> PyTree:
    """Pytree congruent with params mapping leaves to ADEL mask layers.

    Blocks get their stacked index (decoder blocks offset by enc_layers —
    backprop reaches the decoder first, so the encoder is 'deeper' / lower
    id). The embedding joins layer 0 (reached last); final norm + head join
    the last layer (reached first).
    """
    Ltot = cfg.n_blocks_total
    ids: dict = {}
    for k, v in params.items():
        if k == "blocks":
            ids[k] = jax.tree.map(
                lambda _: jnp.arange(cfg.L, dtype=jnp.int32) + cfg.enc_layers, v)
        elif k == "enc_blocks":
            ids[k] = jax.tree.map(
                lambda _: jnp.arange(cfg.enc_layers, dtype=jnp.int32), v)
        elif k == "embed":
            ids[k] = jnp.int32(0)
        else:  # final_norm, enc_norm, lm_head
            ids[k] = jax.tree.map(lambda _: jnp.int32(Ltot - 1), v)
    return ids


def param_specs(params: PyTree, cfg: ArchConfig, *, fsdp: str | tuple = "data",
                tp: str = "model") -> PyTree:
    """PartitionSpec tree: 2D (fsdp x tensor) sharding.

    Big matrices shard their input dim over ``fsdp`` (the data axis — ZeRO-3
    style, layers re-gathered one at a time under the scan) and their output/
    feature dim over ``tp``. Vectors/norms replicate. The stacked L axis is
    NEVER sharded (ADEL masks index it).
    """
    from jax.sharding import PartitionSpec as P

    def spec(path, leaf):
        keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        name = keys[-1] if keys else ""
        nd = leaf.ndim
        if name == "embed":
            return P(tp, fsdp)
        if name == "lm_head":
            return P(fsdp, tp)
        if name == "router":
            return P(None, fsdp, None)
        if name in ("wg", "wu") and nd == 4:          # experts (L, E, D, F)
            return P(None, fsdp, None, tp)
        if name == "wd" and nd == 4:                  # (L, E, F, D)
            return P(None, fsdp, tp, None)
        if name in ("wq", "wk", "wv", "wg", "wu", "w_dkv", "w_uk", "w_uv",
                    "w_kr", "in_proj") and nd == 3:   # (L, D, F)
            return P(None, fsdp, tp)
        if name in ("wo", "wd", "out_proj") and nd == 3:  # (L, F, D)
            return P(None, tp, fsdp)
        if name in ("bq", "bk", "bv") and nd == 2:    # (L, F)
            return P(None, tp)
        return P(*([None] * nd))                      # norms, scalars, conv

    return jax.tree_util.tree_map_with_path(spec, params)


def cache_specs(cache: Cache, cfg: ArchConfig, *, batch="data", tp="model"):
    """Cache sharding: batch over the data axes, head_dim over ``tp`` for KV
    caches (so decode attention reduces over tp with one psum per layer);
    MLA latent dim over ``tp``; SSM state-heads over ``tp``."""
    from jax.sharding import PartitionSpec as P

    def kv_spec(x):
        return P(None, batch, None, None, tp)         # (L,B,S,KV,hd)

    kv = None if cache.kv is None else tuple(kv_spec(x) for x in cache.kv)
    mla = None if cache.mla is None else (
        P(None, batch, None, tp), P(None, batch, None, None))
    ssm = None if cache.ssm is None else (
        P(None, batch, tp, None, None), P(None, batch, None, tp))
    cross = None if cache.cross is None else tuple(
        kv_spec(x) for x in cache.cross)
    return Cache(kv=kv, mla=mla, ssm=ssm, cross=cross)
