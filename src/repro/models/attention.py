"""Attention primitives: GQA (optionally sliding-window), RoPE (full / half
"2d" ChatGLM-style), KV caches (full and rolling-window), and MLA
(DeepSeek-V2 multi-head latent attention) with a compressed KV cache.

All functions are pure jnp; the Pallas flash-attention kernel in
``repro.kernels`` is an optional drop-in for the prefill path (see ops.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["rope", "gqa_attention", "decode_attention", "mla_prefill",
           "mla_decode"]


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def _rope_angles(positions: jnp.ndarray, dim: int, theta: float) -> tuple:
    """positions (...,) -> (cos, sin) of shape (..., dim//2), float32."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def rope(x: jnp.ndarray, positions: jnp.ndarray, *, mode: str = "full",
         theta: float = 10000.0) -> jnp.ndarray:
    """Apply rotary embedding. x: (B, S, H, hd); positions: (B, S) or (S,).

    mode: "full" rotates the whole head dim; "half" (ChatGLM 2d-RoPE style)
    rotates only the first half and passes the rest through; "none" is id.
    """
    if mode == "none":
        return x
    hd = x.shape[-1]
    rot = hd if mode == "full" else hd // 2
    xr, xp = x[..., :rot], x[..., rot:]
    cos, sin = _rope_angles(positions, rot, theta)          # (B, S, rot/2)
    cos = cos[..., None, :].astype(x.dtype)                 # (B, S, 1, rot/2)
    sin = sin[..., None, :].astype(x.dtype)
    x1, x2 = jnp.split(xr, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out, xp], axis=-1) if rot < hd else out


# ---------------------------------------------------------------------------
# GQA attention (training / prefill)
# ---------------------------------------------------------------------------

def gqa_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, window: int = 0,
                  q_pos0: int | jnp.ndarray = 0) -> jnp.ndarray:
    """q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd); H = KV * G. Returns (B, Sq, H, hd).

    ``window`` > 0 restricts attention to the last ``window`` keys
    (sliding-window attention). ``q_pos0`` is the absolute position of the
    first query (for prefill continuation / decode).
    """
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qr = q.reshape(B, Sq, KV, G, hd)
    scale = hd ** -0.5
    scores = jnp.einsum("bskgd,btkd->bkgst", qr.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale      # (B,KV,G,Sq,Sk)
    qpos = q_pos0 + jnp.arange(Sq)
    kpos = jnp.arange(k.shape[1])
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > qpos[:, None] - window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v)
    return out.reshape(B, Sq, H, hd)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     n_valid: jnp.ndarray, *, rolling: bool = False) -> jnp.ndarray:
    """Single-token decode. q: (B, 1, H, hd); caches: (B, S, KV, hd).

    ``n_valid``: number of valid cache entries (scalar). With ``rolling=True``
    (sliding-window cache) every slot is valid once the window has filled;
    validity is still bounded by ``n_valid`` for the warm-up phase.
    """
    B, _, H, hd = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    qr = q.reshape(B, KV, G, hd)
    scale = hd ** -0.5
    scores = jnp.einsum("bkgd,btkd->bkgt", qr.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) * scale   # (B,KV,G,S)
    slot = jnp.arange(k_cache.shape[1])
    valid = slot < n_valid
    scores = jnp.where(valid[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", probs.astype(v_cache.dtype), v_cache)
    return out.reshape(B, 1, H, hd)


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, DeepSeek-V2) — compressed KV cache
# ---------------------------------------------------------------------------

def mla_prefill(x: jnp.ndarray, p: dict, cfg, positions: jnp.ndarray):
    """Prefill/train MLA. x: (B, S, D). Returns (attn_out (B,S,D), (c_kv, k_pe)).

    Params p: wq (D, H*(dn+dr)), w_dkv (D, c), w_uk (c, H*dn), w_uv (c, H*dv),
    w_kr (D, dr), wo (H*dv, D).
    """
    B, S, D = x.shape
    H, dn, dr, dv, c = cfg.n_heads, cfg.mla_nope_dim, cfg.mla_rope_dim, \
        cfg.mla_v_dim, cfg.kv_lora
    q = (x @ p["wq"]).reshape(B, S, H, dn + dr)
    q_nope, q_pe = q[..., :dn], q[..., dn:]
    q_pe = rope(q_pe, positions, mode="full", theta=cfg.rope_theta)
    c_kv = x @ p["w_dkv"]                                   # (B, S, c)
    k_pe = rope((x @ p["w_kr"])[:, :, None, :], positions,
                mode="full", theta=cfg.rope_theta)[:, :, 0]  # (B, S, dr) shared
    k_nope = (c_kv @ p["w_uk"]).reshape(B, S, H, dn)
    v = (c_kv @ p["w_uv"]).reshape(B, S, H, dv)
    scale = (dn + dr) ** -0.5
    s_nope = jnp.einsum("bshd,bthd->bhst", q_nope.astype(jnp.float32),
                        k_nope.astype(jnp.float32))
    s_pe = jnp.einsum("bshd,btd->bhst", q_pe.astype(jnp.float32),
                      k_pe.astype(jnp.float32))
    scores = (s_nope + s_pe) * scale
    qpos = jnp.arange(S)
    mask = qpos[None, :] <= qpos[:, None]
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", probs.astype(v.dtype), v)
    return out.reshape(B, S, H * dv) @ p["wo"], (c_kv, k_pe)


def mla_decode(x: jnp.ndarray, p: dict, cfg, c_cache: jnp.ndarray,
               kpe_cache: jnp.ndarray, pos: jnp.ndarray):
    """Absorbed-matrix MLA decode: scores computed against the COMPRESSED
    cache (c_kv, k_pe) without re-expanding K/V — the latent cache is the
    whole point of MLA. x: (B, 1, D); c_cache: (B, S, c); kpe: (B, S, dr).
    """
    B, _, D = x.shape
    H, dn, dr, dv, c = cfg.n_heads, cfg.mla_nope_dim, cfg.mla_rope_dim, \
        cfg.mla_v_dim, cfg.kv_lora
    q = (x @ p["wq"]).reshape(B, 1, H, dn + dr)
    q_nope, q_pe = q[..., :dn], q[..., dn:]
    q_pe = rope(q_pe, pos[None, None], mode="full", theta=cfg.rope_theta)
    # absorb W_uk into the query: q_c (B, H, c)
    w_uk = p["w_uk"].reshape(c, H, dn)
    q_c = jnp.einsum("bhd,chd->bhc", q_nope[:, 0].astype(jnp.float32),
                     w_uk.astype(jnp.float32))
    s_c = jnp.einsum("bhc,btc->bht", q_c, c_cache.astype(jnp.float32))
    s_pe = jnp.einsum("bhd,btd->bht", q_pe[:, 0].astype(jnp.float32),
                      kpe_cache.astype(jnp.float32))
    scores = (s_c + s_pe) * ((dn + dr) ** -0.5)
    valid = jnp.arange(c_cache.shape[1]) < pos + 1
    scores = jnp.where(valid[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)                  # (B, H, S)
    # attend in latent space then expand through W_uv (absorbed output)
    ctx_c = jnp.einsum("bht,btc->bhc", probs, c_cache.astype(jnp.float32))
    w_uv = p["w_uv"].reshape(c, H, dv)
    ctx = jnp.einsum("bhc,chd->bhd", ctx_c, w_uv.astype(jnp.float32))
    out = ctx.reshape(B, 1, H * dv).astype(x.dtype)
    return out @ p["wo"]
