"""Mixture-of-Experts FFN with token-choice top-k routing, fixed expert
capacity, and a load-balance auxiliary loss (Switch/GShard style).

Dispatch avoids the O(tokens x experts x capacity) one-hot tensors of the
classic GShard einsum formulation (prohibitive at 128 experts): assignments
are positioned with a cumulative-sum within each expert and scattered into a
compact (E, C, D) buffer, matmul'd per expert, and combined back with the
router weights. Experts are sharded on the "model" mesh axis (expert
parallelism); tokens live on the data axes, so the scatter/gather pair is
the all-to-all boundary of the layer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["moe_ffn", "router_topk"]


def router_topk(x2d: jnp.ndarray, w_router: jnp.ndarray, top_k: int):
    """Token-choice routing. x2d: (N, D) -> (weights (N,K), experts (N,K), aux).

    aux is the Switch load-balance loss: E * sum_e f_e * p_e.
    """
    logits = x2d.astype(jnp.float32) @ w_router.astype(jnp.float32)  # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, top_k)                             # (N, K)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    E = w_router.shape[1]
    f = jnp.zeros(E).at[idx.reshape(-1)].add(1.0) / (idx.size)
    p = probs.mean(0)
    aux = E * jnp.sum(f * p)
    return w, idx, aux


def moe_ffn(x2d: jnp.ndarray, p: dict, *, top_k: int,
            capacity_factor: float = 1.25, activation=jax.nn.silu):
    """x2d: (N, D). Params p: router (D, E), wg/wu (E, D, F), wd (E, F, D).

    Returns (out (N, D), aux_loss scalar).
    """
    N, D = x2d.shape
    E = p["router"].shape[1]
    K = top_k
    C = max(int(N * K * capacity_factor / E), 1)

    weights, experts, aux = router_topk(x2d, p["router"], K)         # (N,K)

    flat_e = experts.reshape(-1)                                     # (N*K,)
    flat_w = weights.reshape(-1)
    token_of = jnp.repeat(jnp.arange(N), K)

    # position of each assignment within its expert (order = flattened index)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)              # (N*K, E)
    pos_in_e = jnp.cumsum(onehot, axis=0) - onehot
    pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
    keep = pos < C                                                   # capacity drop
    slot = flat_e * C + jnp.where(keep, pos, 0)

    # dispatch: (E*C, D) buffer
    buf = jnp.zeros((E * C, D), x2d.dtype)
    src = jnp.where(keep[:, None], x2d[token_of], 0)
    buf = buf.at[slot].add(jnp.where(keep[:, None], src, 0))
    buf = buf.reshape(E, C, D)

    # expert computation (SwiGLU)
    g = jnp.einsum("ecd,edf->ecf", buf, p["wg"])
    u = jnp.einsum("ecd,edf->ecf", buf, p["wu"])
    h = activation(g) * u
    y = jnp.einsum("ecf,efd->ecd", h, p["wd"]).reshape(E * C, D)

    # combine: gather each assignment's expert output, weight, and sum per token
    gathered = jnp.where(keep[:, None], y[slot], 0)                  # (N*K, D)
    out = jnp.zeros((N, D), x2d.dtype)
    out = out.at[token_of].add(gathered * flat_w[:, None].astype(x2d.dtype))
    return out, aux
