"""Tiny pure-JAX NN building blocks for the paper-scale models."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["dense_init", "conv_init", "conv2d", "maxpool2d", "group_norm",
           "cross_entropy"]


def dense_init(key, d_in: int, d_out: int, scale: float | None = None):
    """He-normal by default; ``scale=0.0`` zero-inits (classifier heads,
    giving exactly log(n_classes) initial CE loss)."""
    scale = scale if scale is not None else float(np.sqrt(2.0 / d_in))
    w = scale * jax.random.normal(key, (d_in, d_out), jnp.float32)
    return {"w": w, "b": jnp.zeros((d_out,), jnp.float32)}


def conv_init(key, k: int, c_in: int, c_out: int):
    scale = float(np.sqrt(2.0 / (k * k * c_in)))
    w = scale * jax.random.normal(key, (k, k, c_in, c_out), jnp.float32)
    return {"w": w, "b": jnp.zeros((c_out,), jnp.float32)}


def conv2d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
           stride: int = 1, padding: str = "SAME") -> jnp.ndarray:
    """NHWC conv with HWIO weights."""
    out = jax.lax.conv_general_dilated(
        x, w, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return out + b


def group_norm(x: jnp.ndarray, g: jnp.ndarray, o: jnp.ndarray,
               groups: int = 8, eps: float = 1e-5) -> jnp.ndarray:
    """GroupNorm over NHWC (the FL-standard replacement for BatchNorm,
    whose batch statistics break under non-IID client data)."""
    N, H, W, C = x.shape
    gs = min(groups, C)
    while C % gs:
        gs -= 1
    xg = x.reshape(N, H, W, gs, C // gs)
    mu = xg.mean((1, 2, 4), keepdims=True)
    var = xg.var((1, 2, 4), keepdims=True)
    xn = ((xg - mu) * jax.lax.rsqrt(var + eps)).reshape(N, H, W, C)
    return xn * g + o


def maxpool2d(x: jnp.ndarray, k: int = 2) -> jnp.ndarray:
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, k, k, 1), "VALID")


def cross_entropy(logits: jnp.ndarray, y: jnp.ndarray,
                  sample_w: jnp.ndarray | None = None) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits, -1)
    nll = -jnp.take_along_axis(logp, y[..., None], -1)[..., 0]
    if sample_w is None:
        return nll.mean()
    return (nll * sample_w).sum()
