"""The paper's own experimental models (Section IV), as ModelAPIs.

* MLP  — two hidden layers (32, 16) + softmax output; L = 3      (MNIST)
* CNN  — two 5x5 convs (pool+ReLU) + two dense layers; L = 4     (MNIST)
* VGG11 / VGG13 — 8/10 convs + 3 dense; L = 11 / 13              (CIFAR-10)

Params are lists of per-layer dicts, so ``layer_ids`` maps each layer's
leaves to its index. ``width_scale`` shrinks channel counts for the CPU-only
container (DESIGN.md §6); HeteroFL width masks are provided for all models.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.fl.server import ModelAPI
from .nn import (conv2d, conv_init, cross_entropy, dense_init, group_norm,
                 maxpool2d)

__all__ = ["make_mlp", "make_cnn", "make_vgg"]


def _layer_ids(params):
    return [jax.tree.map(lambda _: jnp.int32(i), layer)
            for i, layer in enumerate(params)]


def _hidden_width_masks(params, ratios: np.ndarray):
    """HeteroFL: client u updates the first ceil(r_u * width) output units of
    every hidden layer (and the matching input slices of the next layer).
    Output layer's units are never width-masked (all clients share the head's
    output dim); its input dim follows the previous layer's kept units.
    """
    L = len(params)

    def mask_for(r):
        masks = []
        prev_keep = None  # fraction kept of the previous layer's outputs
        for i, layer in enumerate(params):
            w = layer["w"]
            out_dim = w.shape[-1]
            keep_out = out_dim if i == L - 1 else max(1, int(np.ceil(r * out_dim)))
            m_w = np.zeros(w.shape, np.float32)
            if w.ndim == 2:  # dense (d_in, d_out)
                in_dim = w.shape[0]
                keep_in = in_dim if prev_keep is None else max(1, int(np.ceil(prev_keep * in_dim)))
                m_w[:keep_in, :keep_out] = 1.0
            else:            # conv (k, k, c_in, c_out)
                c_in = w.shape[2]
                keep_in = c_in if prev_keep is None else max(1, int(np.ceil(prev_keep * c_in)))
                m_w[:, :, :keep_in, :keep_out] = 1.0
            layer_mask = {"w": jnp.asarray(m_w)}
            for key, leaf in layer.items():
                if key == "w":
                    continue
                # 1-D per-output-unit params (bias, norm scale/offset)
                m = np.zeros(leaf.shape, np.float32)
                m[:keep_out] = 1.0
                layer_mask[key] = jnp.asarray(m)
            masks.append(layer_mask)
            prev_keep = None if i == L - 1 else r
        return masks

    per_client = [mask_for(float(r)) for r in ratios]
    return jax.tree.map(lambda *ms: jnp.stack(ms), *per_client)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def make_mlp(input_dim: int = 784, hidden: Sequence[int] = (32, 16),
             n_classes: int = 10) -> ModelAPI:
    dims = [input_dim, *hidden, n_classes]
    L = len(dims) - 1

    def init(key):
        keys = jax.random.split(key, L)
        return [dense_init(k, dims[i], dims[i + 1],
                           scale=0.0 if i == L - 1 else None)
                for i, k in enumerate(keys)]

    def forward(params, x):
        h = x.reshape(x.shape[0], -1)
        for i, layer in enumerate(params):
            h = h @ layer["w"] + layer["b"]
            if i < L - 1:
                h = jax.nn.relu(h)
        return h

    def loss(params, x, y, w):
        return cross_entropy(forward(params, x), y, w)

    return ModelAPI(init=init, loss=loss, predict=forward,
                    layer_ids=_layer_ids, L=L, name="mlp",
                    width_masks=_hidden_width_masks)


# ---------------------------------------------------------------------------
# CNN (two 5x5 convs + two dense)
# ---------------------------------------------------------------------------

def make_cnn(in_hw: int = 28, in_c: int = 1, n_classes: int = 10,
             c1: int = 8, c2: int = 16, fc: int = 64) -> ModelAPI:
    L = 4
    flat_hw = in_hw // 4

    def init(key):
        k1, k2, k3, k4 = jax.random.split(key, 4)
        return [conv_init(k1, 5, in_c, c1),
                conv_init(k2, 5, c1, c2),
                dense_init(k3, flat_hw * flat_hw * c2, fc),
                dense_init(k4, fc, n_classes, scale=0.0)]

    def forward(params, x):
        h = jax.nn.relu(maxpool2d(conv2d(x, params[0]["w"], params[0]["b"])))
        h = jax.nn.relu(maxpool2d(conv2d(h, params[1]["w"], params[1]["b"])))
        h = h.reshape(h.shape[0], -1)
        h = jax.nn.relu(h @ params[2]["w"] + params[2]["b"])
        return h @ params[3]["w"] + params[3]["b"]

    def loss(params, x, y, w):
        return cross_entropy(forward(params, x), y, w)

    return ModelAPI(init=init, loss=loss, predict=forward,
                    layer_ids=_layer_ids, L=L, name="cnn",
                    width_masks=_hidden_width_masks)


# ---------------------------------------------------------------------------
# VGG-11 / VGG-13 (Simonyan & Zisserman), width-scalable
# ---------------------------------------------------------------------------

_VGG_PLANS = {
    11: [64, "M", 128, "M", 256, 256, "M", 512, 512, "M", 512, 512, "M"],
    13: [64, 64, "M", 128, 128, "M", 256, 256, "M", 512, 512, "M", 512, 512, "M"],
}


def make_vgg(depth: int = 11, in_hw: int = 32, in_c: int = 3,
             n_classes: int = 10, width_scale: float = 1.0,
             fc_dim: int = 512) -> ModelAPI:
    plan = _VGG_PLANS[depth]
    convs = [(max(4, int(c * width_scale)) if c != "M" else "M") for c in plan]
    n_conv = sum(1 for c in convs if c != "M")
    fc_dim = max(8, int(fc_dim * width_scale))
    L = n_conv + 3
    n_pool = sum(1 for c in convs if c == "M")
    final_hw = in_hw // (2 ** n_pool)
    last_c = [c for c in convs if c != "M"][-1]
    flat = final_hw * final_hw * last_c

    def init(key):
        keys = jax.random.split(key, L)
        params = []
        c_prev, ki = in_c, 0
        for c in convs:
            if c == "M":
                continue
            layer = conv_init(keys[ki], 3, c_prev, c)
            # GroupNorm affine params (FL-standard BatchNorm replacement;
            # BN batch statistics break under non-IID clients) — same
            # per-layer dict, so ADEL's layer masks cover them.
            layer["g"] = jnp.ones((c,), jnp.float32)
            layer["o"] = jnp.zeros((c,), jnp.float32)
            params.append(layer)
            c_prev, ki = c, ki + 1
        params.append(dense_init(keys[ki], flat, fc_dim)); ki += 1
        params.append(dense_init(keys[ki], fc_dim, fc_dim)); ki += 1
        params.append(dense_init(keys[ki], fc_dim, n_classes, scale=0.0))
        return params

    def forward(params, x):
        h = x
        pi = 0
        for c in convs:
            if c == "M":
                h = maxpool2d(h)
            else:
                p = params[pi]
                h = conv2d(h, p["w"], p["b"])
                h = jax.nn.relu(group_norm(h, p["g"], p["o"]))
                pi += 1
        h = h.reshape(h.shape[0], -1)
        h = jax.nn.relu(h @ params[pi]["w"] + params[pi]["b"]); pi += 1
        h = jax.nn.relu(h @ params[pi]["w"] + params[pi]["b"]); pi += 1
        return h @ params[pi]["w"] + params[pi]["b"]

    def loss(params, x, y, w):
        return cross_entropy(forward(params, x), y, w)

    return ModelAPI(init=init, loss=loss, predict=forward,
                    layer_ids=_layer_ids, L=L, name=f"vgg{depth}",
                    width_masks=_hidden_width_masks)
