"""Mamba2 SSD (state-space duality) blocks: chunked parallel scan for
train/prefill and O(1)-state single-token decode.

SSD recurrence (per head, head dim P, state dim N):

    h_t = a_t * h_{t-1} + b_t x_t^T        h in R^{N x P}
    y_t = c_t^T h_t                        (+ D x_t skip)

with a_t = exp(-softplus(A_log) * dt_t) scalar per head, b_t, c_t in R^N
(shared across heads in the Mamba2 "multi-value" layout), x_t in R^P.

The chunked algorithm (arXiv:2405.21060 §6) splits the sequence into chunks
of length Q: within-chunk terms are a masked matmul (the "duality" — it is
exactly causal linear attention), and the cross-chunk term is a short
sequential scan over chunk states. Both are MXU-friendly; the Pallas kernel
in ``repro.kernels.ssd_scan`` implements the same algorithm with explicit
VMEM tiling and is validated against :func:`ssd_reference` here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["ssd_reference", "ssd_chunked", "ssd_decode_step"]


def ssd_reference(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                  b: jnp.ndarray, c: jnp.ndarray,
                  h0: jnp.ndarray | None = None):
    """Sequential-scan oracle. Shapes:

    x: (B, S, H, P)   inputs per head
    dt: (B, S, H)     positive step sizes (post-softplus)
    A: (H,)           positive decay rates (post-softplus of A_log)
    b, c: (B, S, N)   input/output projections (shared across heads)
    h0: (B, H, N, P)  optional initial state.

    Returns (y (B,S,H,P), h_final (B,H,N,P)).
    """
    B, S, H, P = x.shape
    N = b.shape[-1]
    a = jnp.exp(-A[None, None, :] * dt)                       # (B, S, H)
    if h0 is None:
        h0 = jnp.zeros((B, H, N, P), jnp.float32)

    def step(h, inputs):
        a_t, x_t, b_t, c_t, dt_t = inputs
        # h: (B, H, N, P)
        upd = jnp.einsum("bn,bhp->bhnp", b_t, x_t * dt_t[..., None])
        h = a_t[..., None, None] * h + upd
        y = jnp.einsum("bn,bhnp->bhp", c_t, h)
        return h, y

    xs = (jnp.moveaxis(a, 1, 0), jnp.moveaxis(x.astype(jnp.float32), 1, 0),
          jnp.moveaxis(b.astype(jnp.float32), 1, 0),
          jnp.moveaxis(c.astype(jnp.float32), 1, 0),
          jnp.moveaxis(dt, 1, 0))
    h_final, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h_final


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                b: jnp.ndarray, c: jnp.ndarray, *, chunk: int = 64,
                h0: jnp.ndarray | None = None):
    """Chunked SSD (the duality form). Same signature as :func:`ssd_reference`.

    S must be a multiple of ``chunk``.
    """
    B, S, H, P = x.shape
    N = b.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nC = S // chunk
    f32 = jnp.float32

    # log-decay per step, cumulative within chunk
    la = (-A[None, None, :] * dt).astype(f32)                 # (B, S, H)
    la = la.reshape(B, nC, chunk, H)
    cum = jnp.cumsum(la, axis=2)                              # (B,nC,Q,H) log prod_{<=i}
    tot = cum[:, :, -1]                                       # (B,nC,H) chunk total

    xc = (x.astype(f32) * dt[..., None]).reshape(B, nC, chunk, H, P)
    bc = b.astype(f32).reshape(B, nC, chunk, N)
    cc = c.astype(f32).reshape(B, nC, chunk, N)

    # ---- within-chunk (dual / linear-attention) term -----------------------
    # L[i, j] = exp(cum_i - cum_j) for i >= j  (decay from j+1..i)
    decay = cum[:, :, :, None, :] - cum[:, :, None, :, :]     # (B,nC,Q,Q,H)
    iq = jnp.arange(chunk)
    causal = (iq[:, None] >= iq[None, :])[None, None, :, :, None]
    Lmat = jnp.where(causal, jnp.exp(decay), 0.0)             # (B,nC,Q,Q,H)
    scores = jnp.einsum("bcin,bcjn->bcij", cc, bc)            # (B,nC,Q,Q)
    y_intra = jnp.einsum("bcij,bcijh,bcjhp->bcihp", scores, Lmat, xc)

    # ---- chunk states ------------------------------------------------------
    # state contribution of chunk k: sum_j exp(tot - cum_j) b_j x_j^T
    w = jnp.exp(tot[:, :, None, :] - cum)                     # (B,nC,Q,H)
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", bc, w, xc)  # (B,nC,H,N,P)

    # ---- cross-chunk sequential scan over nC chunk states ------------------
    if h0 is None:
        h0 = jnp.zeros((B, H, N, P), f32)

    def chunk_step(h, inp):
        st, lt = inp                                          # (B,H,N,P), (B,H)
        h_in = h                                              # state entering chunk
        h = jnp.exp(lt)[..., None, None] * h + st
        return h, h_in

    h_final, h_ins = jax.lax.scan(
        chunk_step, h0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(tot, 1, 0)))
    h_ins = jnp.moveaxis(h_ins, 0, 1)                         # (B,nC,H,N,P)

    # ---- inter-chunk output term -------------------------------------------
    y_inter = jnp.einsum("bcin,bcih,bchnp->bcihp", cc, jnp.exp(cum), h_ins)

    y = (y_intra + y_inter).reshape(B, S, H, P).astype(x.dtype)
    return y, h_final


def ssd_decode_step(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                    b: jnp.ndarray, c: jnp.ndarray, h: jnp.ndarray):
    """One-token decode. x: (B,H,P); dt: (B,H); b,c: (B,N); h: (B,H,N,P).

    Returns (y (B,H,P), h_next).
    """
    a = jnp.exp(-A[None, :] * dt)                             # (B,H)
    upd = jnp.einsum("bn,bhp->bhnp", b.astype(jnp.float32),
                     x.astype(jnp.float32) * dt[..., None])
    h = a[..., None, None] * h + upd
    y = jnp.einsum("bn,bhnp->bhp", c.astype(jnp.float32), h)
    return y.astype(x.dtype), h
