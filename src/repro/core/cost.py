"""Theorem-1 convergence bound: the Problem-2 objective of ADEL-FL.

    E||w_{R+1} - w_opt||^2 <= prod_t (1 - eta_t rho_c) * Delta_1
        + sum_t eta_t^2 (B_t + C_t) * prod_{tau>t} (1 - eta_tau rho_c)

with (Eq. 11)

    B_t = (1/U^2) sum_u sigma_u^2 / (m P_u (T_t - B_u)/T_t - 1) + 6 rho_s Gamma
    C_t = G^2 4U/(U-1) sum_l (1 + Q(L+1-l, T_t/m)^U) / (1 - 5 Q(L+1-l, T_t/m)^U)

All functions are pure JAX and differentiable in (T, m) so the scheduler can
drive them with jax.grad (Adam path) or hand scipy exact gradients
(trust-region path, as in the paper).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .gamma import log_q_gamma_all
from .types import AnalysisConfig

__all__ = ["b_term", "c_term", "p1_round", "theorem1_bound",
           "objective_and_penalty", "upload_bytes"]

_EPS = 1e-6


def _u_vec(cfg: AnalysisConfig) -> jnp.ndarray:
    """Per-round contributor count, shape (R,): ``U_round`` when the config
    carries an availability forecast, else the static ``U``."""
    if cfg.U_round is None:
        return jnp.full((cfg.R,), float(cfg.U))
    return jnp.asarray(cfg.U_round)


def b_term(T: jnp.ndarray, m: jnp.ndarray, cfg: AnalysisConfig) -> jnp.ndarray:
    """Stochastic-gradient variance term B_t. T: (R,) -> (R,).

    With a ``U_round`` forecast, round t averages ~U_round[t] clients drawn
    from the U-sized representative spread: (1/U_t^2) sum over the round's
    cohort ~= sum over the representative spread / (U_t * U).
    """
    P = jnp.asarray(cfg.P)          # (U,)
    B = jnp.asarray(cfg.B_eff)      # (U,) — wire-compressed comm time
    s2 = jnp.asarray(cfg.sigma2)    # (U,)
    frac = (T[:, None] - B[None, :]) / jnp.maximum(T[:, None], _EPS)   # (R, U)
    denom = m * P[None, :] * frac - 1.0                                 # (R, U)
    denom = jnp.maximum(denom, _EPS)  # feasibility enforced by the solver's penalty
    u = _u_vec(cfg)                                                     # (R,)
    return (s2[None, :] / denom).sum(-1) / (u * cfg.U) \
        + 6.0 * cfg.rho_s * cfg.het_gap


def _log_qU(T: jnp.ndarray, m: jnp.ndarray, cfg: AnalysisConfig) -> jnp.ndarray:
    """U_t * log Q(L+1-l, T_t/m) for l = 1..L; shape (R, L) (layer l at
    index l-1). U_t is per-round under a ``U_round`` forecast."""
    x = T / jnp.maximum(m, _EPS)                     # (R,)
    logq = log_q_gamma_all(cfg.L, x)                 # (R, L); [..., s-1] = log Q(s, x)
    logq = jnp.flip(logq, axis=-1)                   # layer l -> Q(L+1-l, x)
    return _u_vec(cfg)[:, None] * logq


def c_term(T: jnp.ndarray, m: jnp.ndarray, cfg: AnalysisConfig) -> jnp.ndarray:
    """Deadline-truncation variance term C_t. T: (R,) -> (R,)."""
    qU = jnp.exp(_log_qU(T, m, cfg))                 # (R, L)
    denom = jnp.maximum(1.0 - 5.0 * qU, _EPS)        # valid iff p_t^1 < 0.2 (solver constraint)
    ratio = (1.0 + qU) / denom
    u = _u_vec(cfg)                                  # (R,)
    return cfg.G2 * (4.0 * u / (u - 1.0)) * ratio.sum(-1)


def p1_round(T: jnp.ndarray, m: jnp.ndarray, cfg: AnalysisConfig) -> jnp.ndarray:
    """p_t^1 bound = Q(L, T_t/m)^U per round (the binding Lemma-1 constraint)."""
    return jnp.exp(_log_qU(T, m, cfg)[:, 0])


def theorem1_bound(T: jnp.ndarray, m: jnp.ndarray, cfg: AnalysisConfig) -> jnp.ndarray:
    """The full right-hand side of Theorem 1 (Eq. 10)."""
    eta = jnp.asarray(cfg.eta)
    decay = 1.0 - eta * cfg.rho_c                    # (R,)
    # prod_{tau=t+1}^{R} decay_tau  for t = 1..R  (exclusive reversed cumprod)
    rev = jnp.cumprod(decay[::-1])                   # rev[k] = prod of last k+1
    tail = jnp.concatenate([rev[::-1][1:], jnp.ones((1,))])  # (R,)
    head = rev[-1]                                   # prod over all rounds
    per_round = eta ** 2 * (b_term(T, m, cfg) + c_term(T, m, cfg))
    return head * cfg.delta1 + (per_round * tail).sum()


def objective_and_penalty(T: jnp.ndarray, m: jnp.ndarray, cfg: AnalysisConfig,
                          *, p1_cap: float = 0.2, penalty_weight: float = 1e4):
    """Objective + smooth penalties for the Problem-2 constraints.

    Penalized constraints (the sum/monotonicity constraints are enforced by
    the solver's parameterization, not here):
      * p_t^1 < p1_cap                      (Lemma-3 validity)
      * m P_u (T_t - B_u)/T_t > 1 + margin  (batch size >= 2 so B_t is finite)
      * T_t > max_u B_u                     (deadline exceeds communication)
    """
    obj = theorem1_bound(T, m, cfg)
    p1 = p1_round(T, m, cfg)
    B = jnp.asarray(cfg.B_eff)
    pen = jnp.sum(jax.nn.relu(p1 - 0.9 * p1_cap) ** 2)
    frac = (T[:, None] - B[None, :]) / jnp.maximum(T[:, None], _EPS)
    denom = m * jnp.asarray(cfg.P)[None, :] * frac - 1.0
    pen += jnp.sum(jax.nn.relu(0.05 - denom) ** 2)
    pen += jnp.sum(jax.nn.relu(B.max() * 1.05 - T) ** 2)
    return obj + penalty_weight * pen, (obj, p1)


def upload_bytes(cfg: AnalysisConfig) -> jnp.ndarray:
    """Bytes-on-the-wire diagnostic, shape (R,): expected upload volume per
    round = contributors * dense float32 payload * compression ratio.

    ``cfg.bytes_full`` is the per-client dense float32 delta size (0 when
    the caller never measured it) and ``cfg.comm_scale`` the wire ratio the
    solver already prices B_u with — so this is the byte cost the Problem-2
    deadline/batch trade-off is implicitly spending against.
    """
    return _u_vec(cfg) * cfg.bytes_full * cfg.comm_scale
