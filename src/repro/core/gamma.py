"""Regularized upper incomplete gamma function Q(s, x) for integer s, in JAX.

The paper's Auxiliary Lemma (Appendix E) gives, for integer s >= 1,

    Q(s, x) = sum_{k=0}^{s-1} x^k e^{-x} / k!   (= P[Poisson(x) <= s-1]).

ADEL-FL evaluates Q(L+1-l, T_t/m) for every layer l in 1..L, i.e. Q(s, x)
for all s in 1..L at a shared x. We therefore expose a vectorized
``q_gamma_all(L, x)`` returning the whole ladder in one cumulative
log-sum-exp pass (stable for large x, differentiable in x).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.scipy.special import gammaln

__all__ = [
    "q_gamma",
    "q_gamma_all",
    "log_q_gamma_all",
    "poisson_cdf",
    "layer_q",
    "p_no_contributor",
]


def _log_poisson_pmf_terms(kmax: int, x: jnp.ndarray) -> jnp.ndarray:
    """log of x^k e^{-x}/k! for k = 0..kmax-1; x may be any broadcastable shape.

    Returns shape x.shape + (kmax,).
    """
    k = jnp.arange(kmax, dtype=jnp.float32)
    x = jnp.asarray(x, dtype=jnp.float32)[..., None]
    # k*log(x) with the k=0, x=0 corner handled (0*log 0 -> 0).
    safe_log = jnp.where(x > 0, jnp.log(jnp.maximum(x, 1e-38)), -jnp.inf)
    klogx = jnp.where(k == 0, 0.0, k * safe_log)
    return klogx - x - gammaln(k + 1.0)


def _cumlogsumexp(a: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """Cumulative logsumexp along ``axis`` (stable, O(n) via associative scan)."""
    return jax.lax.associative_scan(jnp.logaddexp, a, axis=axis)


def log_q_gamma_all(smax: int, x: jnp.ndarray) -> jnp.ndarray:
    """log Q(s, x) for s = 1..smax, vectorized.

    Returns shape x.shape + (smax,), entry [..., s-1] = log Q(s, x).
    """
    terms = _log_poisson_pmf_terms(smax, x)
    return jnp.minimum(_cumlogsumexp(terms, axis=-1), 0.0)


def q_gamma_all(smax: int, x: jnp.ndarray) -> jnp.ndarray:
    """Q(s, x) for s = 1..smax (shape x.shape + (smax,))."""
    return jnp.exp(log_q_gamma_all(smax, x))


def q_gamma(s: int, x) -> jnp.ndarray:
    """Scalar-s Q(s, x) = P[Poisson(x) <= s-1]."""
    return q_gamma_all(int(s), x)[..., -1]


def poisson_cdf(k: int, lam) -> jnp.ndarray:
    """P[Poisson(lam) <= k] = Q(k+1, lam); k >= 0 integer."""
    return q_gamma(int(k) + 1, lam)


def layer_q(L: int, x) -> jnp.ndarray:
    """Per-layer Q ladder used throughout the paper.

    Returns q[l-1] = Q(L+1-l, x) for l = 1..L; shape x.shape + (L,).

    Backprop reaches layer L (output side) first: reaching layer l requires
    z >= L+1-l completed layer-gradients, so the miss probability per user is
    P[Poisson(x) <= L-l] = Q(L+1-l, x). Layer L gets Q(1, x) = e^{-x}
    (smallest); layer 1 gets Q(L, x) (largest) — matching the paper's
    "p_t^l is monotonically decreasing with the layer index l".
    """
    q = q_gamma_all(L, x)  # [..., s-1] = Q(s, x), s = 1..L
    return jnp.flip(q, axis=-1)  # layer l at index l-1 -> Q(L+1-l, x)


def p_no_contributor(L: int, x, U: int) -> jnp.ndarray:
    """Lemma 1 bound: p_t^l <= Q(L+1-l, x)^U, for l = 1..L (x = T_t^d / m)."""
    logq = jnp.flip(log_q_gamma_all(L, x), axis=-1)
    return jnp.exp(U * logq)


def q_inv(s: int, target: float, *, iters: int = 80) -> float:
    """Solve Q(s, x) = target for x (Q monotone decreasing in x).

    Used by the Problem-2 solver to turn the Lemma-3 validity constraint
    p_t^1 = Q(L, T_t/m)^U < cap into a hard lower bound T_t >= m * x_min
    with x_min = q_inv(L, cap**(1/U)).
    """
    import numpy as np
    target = float(np.clip(target, 1e-30, 1.0 - 1e-12))
    lo, hi = 0.0, float(s + 20.0 * np.sqrt(s) + 50.0)
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        if float(q_gamma(s, jnp.float32(mid))) > target:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)
