"""Straggler model of ADEL-FL (Model Formulations B1-B3, Appendix A).

Per-layer backprop time of user u is Exp(S_t^u / P_u) (mean S/P), so the
number of layer-gradients completed within the effective deadline
T_t^d - B_u is z_t^u ~ Poisson(lambda_t^u) with

    lambda_t^u = P_u / S_t^u * (T_t^d - B_u).

Backprop runs from the output layer L toward the input layer 1: user u
contributes layer l iff z_t^u >= L + 1 - l.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .gamma import log_q_gamma_all
from .types import AnalysisConfig

__all__ = [
    "batch_sizes",
    "poisson_rates",
    "sample_depths",
    "contribution_mask",
    "exact_p_layers",
    "late_p_layers",
    "late_arrival_delays",
    "sample_round",
]


def batch_sizes(T_d, m, P, B) -> jnp.ndarray:
    """Model Formulation B3: S^u = floor(m P_u (T^d - B_u)/T^d), clipped >= 1."""
    T_d = jnp.asarray(T_d, jnp.float32)
    S = jnp.floor(m * P * jnp.maximum(T_d - B, 0.0) / jnp.maximum(T_d, 1e-9))
    return jnp.maximum(S, 1.0)


def poisson_rates(T_d, m, P, B) -> jnp.ndarray:
    """lambda^u = P_u / S^u * (T^d - B_u), with S^u from B3 (Eq. A.2)."""
    S = batch_sizes(T_d, m, P, B)
    return P / S * jnp.maximum(jnp.asarray(T_d, jnp.float32) - B, 0.0)


def sample_depths(key: jax.Array, lam: jnp.ndarray) -> jnp.ndarray:
    """z^u ~ Poisson(lambda^u): number of layers completed (unbounded)."""
    return jax.random.poisson(key, lam)


def contribution_mask(z: jnp.ndarray, L: int) -> jnp.ndarray:
    """mask[u, l-1] = 1 iff user u contributes layer l, i.e. z_u >= L + 1 - l.

    Column index i = l-1 corresponds to threshold L - i.
    """
    thresh = L - jnp.arange(L)          # (L,) = L, L-1, ..., 1
    return (z[:, None] >= thresh[None, :]).astype(jnp.float32)


def exact_p_layers(lam: jnp.ndarray, L: int) -> jnp.ndarray:
    """Exact p_t^l = prod_u P[z_u <= L - l] = prod_u Q(L+1-l, lambda_u).

    Tighter than the Lemma-1 bound (which lower-bounds every lambda_u by
    T_t/m); used by the server for the bias correction in Eq. (5).
    Returns shape (L,), entry l-1 = p_t^l.
    """
    logq = log_q_gamma_all(L, lam)          # (U, L): [u, s-1] = log Q(s, lam_u)
    logp = jnp.flip(logq.sum(0), axis=-1)   # layer l -> sum_u log Q(L+1-l, ·)
    return jnp.exp(logp)


def late_p_layers(lam: jnp.ndarray, L: int) -> jnp.ndarray:
    """Exact zero-LATE-contributor probability per layer.

    The buffered (semi-async) backend folds the COMPLEMENT of the on-time
    set: user u is late at layer l iff z_u < L + 1 - l. Mirroring
    :func:`exact_p_layers`, the probability that NO user is late at layer l
    is ``prod_u (1 - Q(L+1-l, lambda_u))`` — the bias-correction constant
    for the Eq. 5 coefficient fold applied to the late mask. Returns shape
    (L,), entry l-1 = p_late^l.
    """
    logq = log_q_gamma_all(L, lam)          # (U, L): [u, s-1] = log Q(s, lam_u)
    q = jnp.flip(jnp.exp(logq), axis=-1)    # [u, l-1] = P[u late at layer l]
    return jnp.prod(1.0 - q, axis=0)


def late_arrival_delays(depth: jnp.ndarray, layer_s: jnp.ndarray,
                        B: jnp.ndarray, L: int) -> jnp.ndarray:
    """Expected extra simulated time (past the deadline) for each straggler
    to finish its remaining ``L - z_u`` layer-gradients and upload.

    Per-layer backprop time is Exp(S/P) with mean ``layer_s = S_u / P_u``
    (the same clock that makes z_u Poisson), so the expected residual work
    is ``max(L - z_u, 0) * S_u / P_u`` plus the comm/setup overhead ``B_u``
    paid again for the late upload. The buffered backend banks a
    straggler's finished layers at deadline time and folds them once the
    simulated clock passes ``round_end + late_arrival_delays(...)``.
    """
    depth = jnp.asarray(depth, jnp.float32)
    rem = jnp.maximum(jnp.float32(L) - depth, 0.0)
    return rem * jnp.asarray(layer_s, jnp.float32) + jnp.asarray(B,
                                                                 jnp.float32)


def sample_round(key: jax.Array, T_d, m, cfg: AnalysisConfig):
    """One round's straggler draw under B3 batch scaling (ADEL-FL):
    (mask (U,L), p (L,), S (U,), z (U,))."""
    P = jnp.asarray(cfg.P)
    B = jnp.asarray(cfg.B_eff)
    lam = poisson_rates(T_d, m, P, B)
    z = sample_depths(key, lam)
    mask = contribution_mask(z, cfg.L)
    p = exact_p_layers(lam, cfg.L)
    return mask, p, batch_sizes(T_d, m, P, B), z


def fixed_batch(T_d, m, cfg: AnalysisConfig) -> jnp.ndarray:
    """The FIXED per-user batch size used by the baselines (SALF / Drop /
    Wait / HeteroFL fix one batch size for everyone; B3's per-user scaling
    is part of ADEL-FL's contribution)."""
    P_mean = float(np.mean(cfg.P))
    B_mean = float(np.mean(cfg.B_eff))
    S = np.floor(m * P_mean * max(T_d - B_mean, 0.0) / max(T_d, 1e-9))
    return jnp.float32(max(S, 1.0))


def sample_round_fixed(key: jax.Array, T_d, S, cfg: AnalysisConfig):
    """Straggler draw with a uniform batch size S for every user: slow
    devices get proportionally fewer layers done (the baselines' regime).
    Returns (mask, p, lam)."""
    P = jnp.asarray(cfg.P)
    B = jnp.asarray(cfg.B_eff)
    lam = P / S * jnp.maximum(jnp.asarray(T_d, jnp.float32) - B, 0.0)
    z = sample_depths(key, lam)
    mask = contribution_mask(z, cfg.L)
    p = exact_p_layers(lam, cfg.L)
    return mask, p, lam


def simulate_p_empirical(T_d: float, m: float, cfg: AnalysisConfig,
                         n_trials: int = 2000, seed: int = 0) -> np.ndarray:
    """Monte-Carlo estimate of p_t^l (for validating Lemma 1 in tests)."""
    key = jax.random.PRNGKey(seed)
    lam = poisson_rates(T_d, m, jnp.asarray(cfg.P), jnp.asarray(cfg.B_eff))
    keys = jax.random.split(key, n_trials)
    z = jax.vmap(lambda k: sample_depths(k, lam))(keys)        # (n, U)
    masks = jax.vmap(lambda zz: contribution_mask(zz, cfg.L))(z)  # (n, U, L)
    none = (masks.sum(1) == 0).astype(jnp.float32)             # (n, L)
    return np.asarray(none.mean(0))
