"""Round policies: ADEL-FL and the paper's four baselines (Section IV).

A policy decides, per round t:
  * the deadline T_t (and hence the simulated round wall-clock),
  * each client's batch size S_t^u,
  * the per-(client, layer) contribution mask,
  * the aggregation rule (bias-corrected layer-wise / plain mean / HeteroFL
    width-overlap mean).

All randomness flows through explicit PRNG keys so runs are reproducible.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import straggler
from .types import AnalysisConfig, Schedule

__all__ = ["RoundPlan", "Policy", "AdelPolicy", "SalfPolicy", "DropPolicy",
           "WaitPolicy", "HeteroFLPolicy", "make_policy"]


@dataclasses.dataclass
class RoundPlan:
    mask: jnp.ndarray          # (U, L) layer contribution mask
    p: jnp.ndarray             # (L,) zero-contributor probabilities (0 where unused)
    batch_sizes: jnp.ndarray   # (U,)
    elapsed: float             # simulated wall-clock consumed by this round
    bias_correct: bool         # Eq. (5) 1/(1-p) correction?
    width_ratios: Optional[np.ndarray] = None   # HeteroFL only


class Policy:
    """Per-round decision maker.

    ``round(key, t, view=None)`` accepts an optional per-round
    :class:`AnalysisConfig` *view* whose ``U``/``P``/``B`` describe the
    cohort actually sampled this round (the fleet engine re-derives one
    per round); with ``view=None`` the policy uses the static config it
    was constructed with, which preserves the original single-population
    behaviour.
    """

    name: str = "base"

    def __init__(self, cfg: AnalysisConfig):
        self.cfg = cfg

    def round(self, key: jax.Array, t: int,
              view: Optional[AnalysisConfig] = None) -> RoundPlan:  # pragma: no cover
        raise NotImplementedError

    def _resolve(self, view: Optional[AnalysisConfig]) -> AnalysisConfig:
        return view if view is not None else self.cfg

    def _fixed_batch(self, view: Optional[AnalysisConfig], T: float):
        """Fixed-batch policies (salf/drop/wait): cached S for the static
        population, re-derived from the cohort view otherwise."""
        if view is None:
            return self.S
        return straggler.fixed_batch(T, self.m, view)

    def describe(self) -> dict:
        return {"name": self.name}


class AdelPolicy(Policy):
    """ADEL-FL: Problem-2-optimized deadlines + B3 batch sizes + Eq. (5)."""

    name = "adel"

    def __init__(self, cfg: AnalysisConfig, schedule: Schedule):
        super().__init__(cfg)
        self.schedule = schedule

    def round(self, key, t, view=None):
        cfg = self._resolve(view)
        T_t = float(self.schedule.T[t])
        mask, p, S, _ = straggler.sample_round(key, T_t, self.schedule.m, cfg)
        return RoundPlan(mask=mask, p=p, batch_sizes=S, elapsed=T_t,
                         bias_correct=True)

    def describe(self):
        return {"name": self.name, "m": self.schedule.m,
                "T": self.schedule.T.tolist(), "solver": self.schedule.solver}


class SalfPolicy(Policy):
    """SALF [31]: layer-wise aggregation with bias correction, but FIXED
    deadline T_max/R and one FIXED batch size for every user (no joint
    optimization, no B3 per-user batch scaling)."""

    name = "salf"

    def __init__(self, cfg: AnalysisConfig, m: float):
        super().__init__(cfg)
        self.m = float(m)
        self.T_t = cfg.T_max / cfg.R
        self.S = straggler.fixed_batch(self.T_t, self.m, cfg)

    def round(self, key, t, view=None):
        cfg = self._resolve(view)
        S_fix = self._fixed_batch(view, self.T_t)
        mask, p, _ = straggler.sample_round_fixed(key, self.T_t, S_fix, cfg)
        S = jnp.full((cfg.U,), S_fix)
        return RoundPlan(mask=mask, p=p, batch_sizes=S, elapsed=self.T_t,
                         bias_correct=True)

    def describe(self):
        return {"name": self.name, "m": self.m, "T": self.T_t,
                "S_fixed": float(self.S)}


class DropPolicy(Policy):
    """Drop-Stragglers [17]: fixed deadline; a client counts only if it
    finished the FULL model in time (z_u >= L); late clients are discarded."""

    name = "drop"

    def __init__(self, cfg: AnalysisConfig, m: float):
        super().__init__(cfg)
        self.m = float(m)
        self.T_t = cfg.T_max / cfg.R
        self.S = straggler.fixed_batch(self.T_t, self.m, cfg)

    def round(self, key, t, view=None):
        cfg = self._resolve(view)
        S_fix = self._fixed_batch(view, self.T_t)
        P, B = jnp.asarray(cfg.P), jnp.asarray(cfg.B_eff)
        lam = P / S_fix * jnp.maximum(self.T_t - B, 0.0)
        z = straggler.sample_depths(key, lam)
        full = (z >= cfg.L).astype(jnp.float32)                  # (U,)
        mask = jnp.broadcast_to(full[:, None], (cfg.U, cfg.L))
        S = jnp.full((cfg.U,), S_fix)
        return RoundPlan(mask=mask, p=jnp.zeros(cfg.L), batch_sizes=S,
                         elapsed=self.T_t, bias_correct=False)


class WaitPolicy(Policy):
    """Wait-Stragglers (vanilla synchronous FedAvg [1]): no deadline; the
    round lasts until the slowest client finishes (max_u Gamma(L, S_u/P_u) +
    B_u), so far fewer rounds fit inside T_max."""

    name = "wait"

    def __init__(self, cfg: AnalysisConfig, m: float):
        super().__init__(cfg)
        self.m = float(m)
        self.T_ref = cfg.T_max / cfg.R
        self.S = straggler.fixed_batch(self.T_ref, self.m, cfg)

    def round(self, key, t, view=None):
        cfg = self._resolve(view)
        S_fix = self._fixed_batch(view, self.T_ref)
        P, B = jnp.asarray(cfg.P), jnp.asarray(cfg.B_eff)
        # full backprop time = sum of L iid Exp(S/P) = Gamma(L, scale=S/P);
        # with a FIXED batch the slowest device dominates the round clock
        g = jax.random.gamma(key, cfg.L, shape=(cfg.U,)) * (S_fix / P)
        elapsed = float(jnp.max(g + B))
        mask = jnp.ones((cfg.U, cfg.L), jnp.float32)
        S = jnp.full((cfg.U,), S_fix)
        return RoundPlan(mask=mask, p=jnp.zeros(cfg.L), batch_sizes=S,
                         elapsed=elapsed, bias_correct=False)


class HeteroFLPolicy(Policy):
    """HeteroFL [30]: clients train width-reduced submodels matched to their
    capability; aggregation averages each parameter entry over the clients
    whose submodel contains it. Compute per layer scales ~ r^2 (both weight
    matrices shrink), so slow clients nearly always finish their small model.

    With a per-round cohort ``view`` (fleet runs) the capability buckets are
    re-derived from the sampled cohort's P, and ``RoundPlan.width_ratios``
    tells the runtime which width masks to build; the width-overlap mean
    runs on every execution backend (``repro.fl.backends``).
    """

    name = "heterofl"
    LEVELS = (1.0, 0.5, 0.25, 0.125)

    def __init__(self, cfg: AnalysisConfig, m: float):
        super().__init__(cfg)
        self.m = float(m)
        self.T_t = cfg.T_max / cfg.R
        self.ratios = self._capability_ratios(cfg.P)

    @classmethod
    def _capability_ratios(cls, P: np.ndarray) -> np.ndarray:
        """Capability-bucketed width ratios: fastest quartile -> 1.0, etc."""
        P = np.asarray(P)
        order = np.argsort(np.argsort(-P))          # rank 0 = fastest
        quart = (order * len(cls.LEVELS)) // len(P)
        return np.asarray([cls.LEVELS[q] for q in quart], np.float32)

    def round(self, key, t, view=None):
        cfg = self._resolve(view)
        ratios = (self.ratios if view is None
                  else self._capability_ratios(cfg.P))
        P, B = jnp.asarray(cfg.P), jnp.asarray(cfg.B_eff)
        S_fix = straggler.fixed_batch(self.T_t, self.m, cfg)
        r = jnp.asarray(ratios)
        # per-layer time Exp(S r^2 / P) -> completed layers ~ Poisson(P (T-B) / (S r^2))
        lam = P / (S_fix * r ** 2) * jnp.maximum(self.T_t - B, 0.0)
        z = straggler.sample_depths(key, lam)
        full = (z >= cfg.L).astype(jnp.float32)
        mask = jnp.broadcast_to(full[:, None], (cfg.U, cfg.L))
        S = jnp.full((cfg.U,), S_fix)
        return RoundPlan(mask=mask, p=jnp.zeros(cfg.L), batch_sizes=S,
                         elapsed=self.T_t, bias_correct=False,
                         width_ratios=ratios)

    def describe(self):
        return {"name": self.name, "m": self.m, "ratios": self.ratios.tolist()}


def make_policy(method: str, cfg: AnalysisConfig, *, schedule: Schedule | None = None,
                m: float | None = None) -> Policy:
    from .scheduler import constant_schedule, solve
    if method == "adel":
        if schedule is None:
            schedule = solve(cfg, "trust-constr")
        return AdelPolicy(cfg, schedule)
    if m is None:
        m = constant_schedule(cfg).m
    if method == "salf":
        return SalfPolicy(cfg, m)
    if method == "drop":
        return DropPolicy(cfg, m)
    if method == "wait":
        return WaitPolicy(cfg, m)
    if method == "heterofl":
        return HeteroFLPolicy(cfg, m)
    raise ValueError(f"unknown method {method!r}")
