"""Layer-wise bias-corrected aggregation (Eq. 5 of the paper), in gradient form.

For client updates w_u^l = w^l - eta * g_u^l, Eq. (5)

    w~_{t+1}^l = w~_t^l                                  if |U_t^l| = 0
               = ( mean_{u in U^l} w_u^l - p^l w~_t^l ) / (1 - p^l)   otherwise

is algebraically equivalent to the *gradient-space* rule

    g~^l = 0                                             if |U_t^l| = 0
         = mean_{u in U^l} g_u^l / (1 - p^l)             otherwise

followed by w~_{t+1} = w~_t - eta g~. We implement the gradient form: it is
a masked weighted reduction over the client axis, which on the TPU mesh is a
single (masked) all-reduce — the paper's server-side aggregation mapped onto
jax.lax collectives.

Parameter->layer mapping: models expose ``layer_ids(params)``, a pytree
congruent with ``params`` whose leaves are int32 arrays of shape
  * ()    — the whole tensor belongs to that layer, or
  * (L,)  — the leading axis is the stacked-layer axis; entry i gives the
            layer id of slice i (normally arange(L)).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

__all__ = [
    "layer_coefficients",
    "weight_by_layer",
    "aggregate_with_coeffs",
    "aggregate_grads",
    "aggregate_grads_chunk",
    "aggregate_grads_local",
    "hetero_overlap_partials",
    "hetero_overlap_mean",
    "masked_mean_grads",
]

PyTree = Any


def layer_coefficients(mask: jnp.ndarray, p: jnp.ndarray,
                       *, bias_correct: bool = True,
                       counts: jnp.ndarray | None = None) -> jnp.ndarray:
    """Per-(client, layer) aggregation coefficient c[u, l].

    agg^l = sum_u c[u, l] g_u^l reproduces Eq. (5):
      c[u, l] = mask[u, l] / count_l / (1 - p_l)   if count_l > 0 else 0.

    ``counts`` may be supplied externally (global counts under shard_map).
    """
    if counts is None:
        counts = mask.sum(0)                      # (L,)
    denom = jnp.maximum(counts, 1.0)
    scale = jnp.where(counts > 0, 1.0, 0.0)
    if bias_correct:
        scale = scale / jnp.maximum(1.0 - p, 1e-6)
    return mask * (scale / denom)[None, :]        # (U, L)


def weight_by_layer(g: jnp.ndarray, ids: jnp.ndarray,
                    c_row: jnp.ndarray) -> jnp.ndarray:
    """Scale ONE client's grad/delta leaf by its per-layer coefficient row.

    This is the Eq. 5 coefficient fold used by temporal (grad-accumulation)
    client layouts: summing ``weight_by_layer(g_u, ids, c[u])`` over clients
    u equals :func:`aggregate_grads` with coefficients ``c`` — but the
    accumulation never holds more than one gradient pytree.

    ``ids``: () whole-tensor layer id, or (L,) stacked-axis ids; ``c_row``:
    (L_total,) this client's coefficients.
    """
    ids = jnp.asarray(ids)
    if ids.ndim == 0:
        return g * c_row[ids]
    w = jnp.take(c_row, ids)                       # (L,)
    return g * w.reshape((-1,) + (1,) * (g.ndim - 1))


def _weight_leaf(g: jnp.ndarray, ids: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """Reduce one grads leaf g of shape (U,)+param.shape with coeffs c (U, L)."""
    ids = jnp.asarray(ids)
    if ids.ndim == 0:
        w = c[:, ids]                             # (U,)
        return jnp.tensordot(w, g, axes=(0, 0))
    # stacked: g is (U, L, ...); weight (U, L) broadcast over trailing dims
    w = jnp.take(c, ids, axis=1)                  # (U, L)
    return jnp.einsum("ul,ul...->l...", w, g)


def aggregate_with_coeffs(grads: PyTree, layer_ids: PyTree,
                          coeffs: jnp.ndarray) -> PyTree:
    """Reduce stacked per-client grads with EXPLICIT coefficients.

    ``agg^l = sum_u coeffs[u, l] g_u^l`` — the raw coefficient fold that
    :func:`aggregate_grads` specializes with the Eq. 5 on-time
    coefficients. The buffered backend calls it directly with
    staleness-decayed late-set coefficients whose (mask, p) were fixed at
    the round the work belongs to.

    grads leaves: (U,) + param.shape; coeffs: (U, L).
    """
    return jax.tree.map(lambda g, ids: _weight_leaf(g, ids, coeffs),
                        grads, layer_ids)


def aggregate_grads(grads: PyTree, layer_ids: PyTree, mask: jnp.ndarray,
                    p: jnp.ndarray, *, bias_correct: bool = True) -> PyTree:
    """ADEL-FL aggregation of stacked per-client grads.

    grads: pytree with a leading client axis U on every leaf.
    mask: (U, L) contribution mask; p: (L,) zero-contributor probabilities.
    Returns the aggregated gradient pytree (no client axis).
    """
    c = layer_coefficients(mask, p, bias_correct=bias_correct)
    return jax.tree.map(lambda g, ids: _weight_leaf(g, ids, c), grads, layer_ids)


def aggregate_grads_local(local_grads: PyTree, layer_ids: PyTree,
                          local_mask: jnp.ndarray, p: jnp.ndarray,
                          axis_name: str | tuple[str, ...],
                          *, bias_correct: bool = True) -> PyTree:
    """shard_map/explicit-collective variant: each shard holds a slice of the
    client axis; counts and weighted sums are combined with jax.lax.psum.

    local_grads leaves: (U_local,) + param.shape; local_mask: (U_local, L).
    """
    counts = jax.lax.psum(local_mask.sum(0), axis_name)       # (L,) global
    c = layer_coefficients(local_mask, p, bias_correct=bias_correct,
                           counts=counts)
    partial = jax.tree.map(lambda g, ids: _weight_leaf(g, ids, c),
                           local_grads, layer_ids)
    return jax.lax.psum(partial, axis_name)


def aggregate_grads_chunk(chunk_grads: PyTree, layer_ids: PyTree,
                          chunk_mask: jnp.ndarray, p: jnp.ndarray,
                          counts: jnp.ndarray, *,
                          bias_correct: bool = True) -> PyTree:
    """Sequential-chunk analogue of :func:`aggregate_grads_local`.

    The caller supplies the GLOBAL per-layer contributor counts and sums the
    returned partial aggregates over chunks — a software psum over the
    client-shard axis, so a large cohort never materializes one stacked
    (cohort, ...) delta pytree. Summing the partials over every chunk is
    exactly ``aggregate_grads`` on the concatenated client axis, and the
    chunk axis maps 1:1 onto a ``shard_map`` client mesh axis (swap the host
    loop for ``jax.lax.psum``).

    The same identity powers hierarchical two-tier aggregation
    (:class:`repro.fl.backends.HierarchicalBackend`): each edge REGION is
    one "chunk" — its partial aggregate, evaluated against the global
    counts, is what crosses the wide-area network, and the global fold is
    just the sum of region partials.

    chunk_grads leaves: (U_chunk,) + param.shape; chunk_mask: (U_chunk, L).
    """
    c = layer_coefficients(chunk_mask, p, bias_correct=bias_correct,
                           counts=counts)
    return jax.tree.map(lambda g, ids: _weight_leaf(g, ids, c),
                        chunk_grads, layer_ids)


def hetero_overlap_partials(deltas: PyTree, wmasks: PyTree,
                            part: jnp.ndarray) -> tuple[PyTree, PyTree]:
    """Per-shard partials of the HeteroFL width-overlap mean.

    HeteroFL averages each parameter ENTRY over the participating clients
    whose width-reduced submodel contains it:

        agg = sum_u part_u wm_u d_u / max(sum_u part_u wm_u, 1)

    Both sums are linear over the client axis, so — exactly like
    :func:`aggregate_grads_chunk` / :func:`aggregate_grads_local` — a
    backend computes local (num, den) partials over its slice of clients
    and combines them with a chunk-sum or ``jax.lax.psum`` before the
    final divide in :func:`hetero_overlap_mean`.

    deltas/wmasks leaves: (U_local,) + param.shape; part: (U_local,)
    participation indicator (all-or-nothing rows of the layer mask).
    """
    def w(wm):
        return part.reshape((-1,) + (1,) * (wm.ndim - 1)) * wm

    num = jax.tree.map(lambda d, wm: (w(wm) * d).sum(0), deltas, wmasks)
    den = jax.tree.map(lambda wm: w(wm).sum(0), wmasks)
    return num, den


def hetero_overlap_mean(num: PyTree, den: PyTree) -> PyTree:
    """Finish the width-overlap mean from globally combined partials;
    entries no participating client covers keep delta 0."""
    return jax.tree.map(lambda n, d: n / jnp.maximum(d, 1.0), num, den)


def masked_mean_grads(grads: PyTree, layer_ids: PyTree,
                      mask: jnp.ndarray) -> PyTree:
    """Plain masked mean without bias correction (Drop-Stragglers-style when
    given an all-or-nothing mask; SALF-without-correction ablation)."""
    p = jnp.zeros(mask.shape[1], mask.dtype)
    return aggregate_grads(grads, layer_ids, mask, p, bias_correct=False)
