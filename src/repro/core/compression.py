"""Delta compression for the client -> server wire (int8 / top-k payloads).

At fleet scale the round bottleneck is moving and reducing U full-precision
delta pytrees. This module defines the compressed wire format and the
aggregation that consumes it directly — the float32 delta tree is never
re-materialized per client:

* ``int8`` — symmetric absmax quantization with one float32 scale per
  (client, layer): ``scale[u, l] = max_f |d[u, l, f]| / 127``,
  ``q = rint(d / scale)`` (deterministic round-to-nearest, so trajectories
  and byte counts are exactly reproducible). 4 bytes/element -> 1 byte.
* ``topk8`` — per-(client, layer) top-k by magnitude over the flattened
  feature dim, int8 values + int32 indices (5 bytes per kept entry), same
  absmax scale. Wire cost ``~1.25 * top_k`` of dense float32.

Every leaf is handled in the canonical kernel layout (U, L_leaf, F):
stacked-layer leaves (layer ids of shape (L,)) flatten trailing dims to F;
whole-tensor leaves are L_leaf = 1. Aggregation folds the Eq. 5 coefficient
``c[u, l]`` INTO the dequant scale, so dequantize + weight + accumulate is
one pass — pure-jnp einsum / scatter-add, or the fused Pallas
``kernels.adel_agg_q8`` when ``agg_impl="pallas"`` (interpret mode on CPU).

The payload crossing the jit/device boundary is a flat list (params-tree
flatten order) of per-leaf tuples ``(q, scale)`` or ``(q, scale, idx)`` —
a plain pytree, so chunked's chunk-sum and shard_map's shard-local
reduction consume int8 rather than float32 trees.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "CompressionConfig",
    "make_compression",
    "compress_deltas",
    "aggregate_compressed",
    "payload_bytes",
]

PyTree = Any

MODES = ("none", "int8", "topk8")


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    """Client->server payload compression spec (hashable; lives inside
    frozen configs such as :class:`repro.configs.base.FleetConfig`).

    ``mode``: "none" | "int8" | "topk8"; ``top_k``: kept fraction of the
    flattened feature dim per (client, layer) in topk8 mode.
    """
    mode: str = "none"
    top_k: float = 0.05

    def __post_init__(self):
        assert self.mode in MODES, f"unknown compression mode {self.mode!r}"
        assert 0.0 < self.top_k <= 1.0

    def wire_scale(self) -> float:
        """Expected wire bytes as a fraction of the dense float32 payload
        (per-layer scale scalars excluded — negligible for real F). This is
        the ``comm_scale`` the Problem-2 cost model prices B_u with."""
        if self.mode == "int8":
            return 0.25
        if self.mode == "topk8":
            return 1.25 * self.top_k          # 1B value + 4B index per kept
        return 1.0


def make_compression(spec) -> CompressionConfig:
    """None | mode string | (mode, top_k) | CompressionConfig -> config."""
    if spec is None:
        return CompressionConfig()
    if isinstance(spec, CompressionConfig):
        return spec
    if isinstance(spec, str):
        return CompressionConfig(mode=spec)
    mode, top_k = spec
    return CompressionConfig(mode=mode, top_k=float(top_k))


def _leaf_dims(shape, ids_ndim: int) -> tuple[int, int]:
    """Canonical (L_leaf, F) of one param leaf."""
    if ids_ndim == 0:
        return 1, int(np.prod(shape)) if shape else 1
    return int(shape[0]), int(np.prod(shape[1:])) if shape[1:] else 1


def _leaf_k(F: int, cfg: CompressionConfig) -> int:
    return max(1, min(F, int(math.ceil(cfg.top_k * F))))


def _compress_leaf(g: jnp.ndarray, ids, cfg: CompressionConfig):
    """One delta leaf (U,) + param.shape -> wire tuple in (U, Ll, F) form."""
    ids = jnp.asarray(ids)
    U = g.shape[0]
    Ll, F = _leaf_dims(g.shape[1:], ids.ndim)
    flat = g.reshape(U, Ll, F).astype(jnp.float32)
    amax = jnp.max(jnp.abs(flat), axis=-1)                    # (U, Ll)
    scale = amax / 127.0
    inv = jnp.where(amax > 0, 127.0 / amax, 0.0)
    if cfg.mode == "int8":
        q = jnp.rint(flat * inv[..., None]).astype(jnp.int8)
        return (q, scale)
    k = _leaf_k(F, cfg)
    _, idx = jax.lax.top_k(jnp.abs(flat), k)                  # (U, Ll, k)
    vals = jnp.take_along_axis(flat, idx, axis=-1)
    q = jnp.rint(vals * inv[..., None]).astype(jnp.int8)
    return (q, scale, idx.astype(jnp.int32))


def compress_deltas(deltas: PyTree, layer_ids: PyTree,
                    cfg: CompressionConfig) -> list:
    """Compress a stacked delta pytree (leading client axis U on every
    leaf) into the wire payload: a flat list, in ``jax.tree.flatten``
    order, of ``(q int8 (U, Ll, F), scale f32 (U, Ll))`` tuples —
    plus ``idx int32 (U, Ll, K)`` in topk8 mode."""
    leaves, _ = jax.tree.flatten(deltas)
    id_leaves, _ = jax.tree.flatten(layer_ids)
    return [_compress_leaf(g, i, cfg) for g, i in zip(leaves, id_leaves)]


def _leaf_coeff_rows(c: jnp.ndarray, ids) -> jnp.ndarray:
    """Eq. 5 coefficient rows for one leaf: (U, Ll)."""
    ids = jnp.asarray(ids)
    if ids.ndim == 0:
        return c[:, ids][:, None]
    return jnp.take(c, ids, axis=1)


def _agg_leaf(entry, param, ids, c, cfg: CompressionConfig,
              agg_impl: str, interpret: bool) -> jnp.ndarray:
    w = _leaf_coeff_rows(c, ids)                              # (U, Ll)
    shape = param.shape
    Ll, F = _leaf_dims(shape, jnp.asarray(ids).ndim)
    if cfg.mode == "topk8":
        q, scale, idx = entry
        contrib = (w * scale)[..., None] * q.astype(jnp.float32)
        l_idx = jnp.broadcast_to(jnp.arange(Ll)[None, :, None], idx.shape)
        out = jnp.zeros((Ll, F), jnp.float32).at[l_idx, idx].add(contrib)
        return out.reshape(shape)
    q, scale = entry
    if agg_impl == "pallas":
        from repro.kernels.adel_agg import adel_agg_q8
        out = adel_agg_q8(q, scale, w, interpret=interpret)
    else:
        out = jnp.einsum("ul,ulf->lf", w * scale, q.astype(jnp.float32))
    return out.reshape(shape)


def aggregate_compressed(payload: list, params: PyTree, layer_ids: PyTree,
                         mask: jnp.ndarray, p: jnp.ndarray, *,
                         cfg: CompressionConfig,
                         counts: jnp.ndarray | None = None,
                         coeffs: jnp.ndarray | None = None,
                         bias_correct: bool = True,
                         agg_impl: str = "jnp",
                         interpret: bool | None = None) -> PyTree:
    """Fused dequantize + Eq. 5 weight + accumulate over the wire payload.

    Returns the aggregated float32 delta pytree (params structure; no
    client axis). ``counts`` supplies GLOBAL per-layer contributor counts
    (chunked / shard-local partials); ``coeffs`` overrides the Eq. 5
    coefficients entirely (temporal's one-client-at-a-time fold against
    cohort-global coefficients). ``params`` is used for leaf shapes only.
    """
    from repro.core.aggregation import layer_coefficients
    if interpret is None:
        from repro.kernels.ops import default_interpret
        interpret = default_interpret()
    if coeffs is None:
        coeffs = layer_coefficients(mask, p, bias_correct=bias_correct,
                                    counts=counts)
    p_leaves, treedef = jax.tree.flatten(params)
    id_leaves, _ = jax.tree.flatten(layer_ids)
    out = [_agg_leaf(e, pl, i, coeffs, cfg, agg_impl, interpret)
           for e, pl, i in zip(payload, p_leaves, id_leaves)]
    return jax.tree.unflatten(treedef, out)


def payload_bytes(params: PyTree, layer_ids: PyTree, U: int,
                  cfg: CompressionConfig) -> tuple[int, int]:
    """Deterministic analytic (logical, wire) byte counts for a U-client
    round payload.

    ``logical`` is the dense float32 delta pytree (4 bytes/element times
    U), independent of the model dtype — the uncompressed baseline every
    mode is measured against. ``wire`` is what the compressed payload
    actually ships: int8 values + float32 per-(client, layer) scales
    (+ int32 indices in topk8 mode).
    """
    logical = wire = 0
    for pleaf, ids in zip(jax.tree.leaves(params),
                          jax.tree.leaves(layer_ids)):
        Ll, F = _leaf_dims(pleaf.shape, getattr(ids, "ndim", 0))
        logical += 4 * Ll * F
        if cfg.mode == "int8":
            wire += Ll * F + 4 * Ll
        elif cfg.mode == "topk8":
            k = _leaf_k(F, cfg)
            wire += 5 * Ll * k + 4 * Ll
        else:
            wire += 4 * Ll * F
    return U * logical, U * wire
