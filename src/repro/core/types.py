"""Shared dataclasses for the ADEL-FL core."""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class AnalysisConfig:
    """Constants of the Theorem-1 convergence bound / Problem-2 objective.

    Mirrors Table I of the paper.
    """

    U: int                      # number of users
    L: int                      # number of DNN layers
    R: int                      # number of global rounds (R1)
    T_max: float                # total training-time budget (R2)
    eta: np.ndarray             # learning-rate schedule, shape (R,)
    rho_c: float                # strong-convexity constant (A1)
    rho_s: float                # smoothness constant (A1)
    sigma2: np.ndarray          # per-user gradient-variance bounds sigma_u^2, shape (U,) (A2)
    G2: float                   # squared gradient-norm bound G^2 (A3)
    het_gap: float              # heterogeneity gap Gamma, Eq. (6)
    P: np.ndarray               # per-user compute capability P_u, shape (U,) (B1)
    B: np.ndarray               # per-user communication time B_u, shape (U,) (B2)
    delta1: float = 1.0         # Delta_1 = E||w_1 - w_opt||^2
    # Availability-aware planning (beyond-paper, repro.core.replan): the
    # EXPECTED plannable cohort size per round, shape (R,). When set, the
    # Theorem-1 terms evaluate round t at U_round[t] contributors (C_t's
    # Q^U truncation, B_t's 1/U^2 averaging) while P/B/sigma2 keep
    # describing a U-sized representative capability spread. None keeps the
    # paper's static-U objective exactly.
    U_round: Optional[np.ndarray] = None
    # Bytes-on-the-wire pricing (repro.core.compression): ``comm_scale``
    # multiplies every B_u — the per-user communication time B2 prices the
    # dense float32 delta upload, and compressing the payload shrinks it by
    # the wire ratio (0.25 for int8, ~1.25*top_k for topk8). All model-side
    # consumers (B_t variance term, B3 batch sizes, solver feasibility,
    # straggler clock draws) read ``B_eff`` so the Problem-2 solver trades
    # batch size against upload bytes consistently. ``bytes_full`` records
    # the dense float32 payload size per client (diagnostic; 0 = unknown).
    comm_scale: float = 1.0
    bytes_full: float = 0.0

    @property
    def B_eff(self) -> np.ndarray:
        """Effective per-user communication time: ``B * comm_scale``."""
        return self.B * np.float32(self.comm_scale)

    def __post_init__(self):
        object.__setattr__(self, "eta", np.asarray(self.eta, np.float32))
        object.__setattr__(self, "sigma2", np.asarray(self.sigma2, np.float32))
        object.__setattr__(self, "P", np.asarray(self.P, np.float32))
        object.__setattr__(self, "B", np.asarray(self.B, np.float32))
        assert self.eta.shape == (self.R,), (self.eta.shape, self.R)
        assert self.sigma2.shape == (self.U,)
        assert self.P.shape == (self.U,)
        assert self.B.shape == (self.U,)
        assert self.comm_scale > 0.0
        if self.U_round is not None:
            u = np.asarray(self.U_round, np.float32)
            object.__setattr__(self, "U_round", u)
            assert u.shape == (self.R,), (u.shape, self.R)
            assert float(u.min()) >= 2.0, "per-round cohorts need >= 2 users"

    @staticmethod
    def default(U: int, L: int, R: int, T_max: float, *,
                eta0: float = 0.1, eta_decay: float = 1.0, seed: int = 0,
                het_spread: float = 4.0,
                base_rate: float = 8.0) -> "AnalysisConfig":
        """A reasonable default with heterogeneous P_u spread.

        ``base_rate`` scales every P_u (samples/sec per layer).  The straggler
        depth statistics are invariant to this scale (lambda_t^u = T_t/m under
        B3), but the *batch sizes* S_t^u = m P_u (1 - B_u/T_t) grow with it —
        real edge devices process many samples/sec, and batch sizes of 1-2
        make SGD needlessly noisy without changing the scheduling math.

        ``eta_decay`` generalizes the paper's inverse decay to
        eta_t = eta0 / (1 + eta_decay * t) (the same family; eta_decay=1
        reproduces the paper's eta0/(1+t); deep models on few rounds need a
        slower decay to make any progress — recorded in EXPERIMENTS.md).
        """
        rng = np.random.default_rng(seed)
        t = np.arange(1, R + 1, dtype=np.float32)
        eta = eta0 / (1.0 + eta_decay * t)
        P = base_rate * np.exp(
            rng.uniform(0.0, np.log(het_spread), size=U)).astype(np.float32)
        B = rng.uniform(0.005, 0.02, size=U).astype(np.float32) * (T_max / R)
        sigma2 = np.full((U,), 1.0, np.float32)
        return AnalysisConfig(
            U=U, L=L, R=R, T_max=float(T_max), eta=eta,
            rho_c=0.1, rho_s=2.0, sigma2=sigma2, G2=1.0, het_gap=0.1,
            P=P, B=B, delta1=1.0,
        )


@dataclasses.dataclass(frozen=True)
class Schedule:
    """Output of the Problem-2 solver: the ADEL-FL round configuration."""

    T: np.ndarray               # per-round deadlines T_t^d, shape (R,), nonincreasing
    m: float                    # global batch-scaling parameter
    objective: float            # achieved Theorem-1 bound value
    p1: np.ndarray              # per-round p_t^1 (layer-1 zero-contributor prob bound)
    solver: str = "adam"

    def batch_sizes(self, cfg: AnalysisConfig) -> np.ndarray:
        """Model Formulation B3: S_t^u = floor(m P_u (T_t - B_u)/T_t), shape
        (R, U). ``B_u`` is the EFFECTIVE communication time (``cfg.B_eff``):
        a compressed wire leaves more of the deadline for compute, so the
        planned batches grow."""
        T = self.T[:, None]
        B = cfg.B_eff
        S = np.floor(self.m * cfg.P[None, :] * (T - B[None, :]) / T)
        return np.maximum(S, 1.0).astype(np.int32)
