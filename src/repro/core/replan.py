"""Online re-planning of the remaining-horizon Problem 2 under churn.

The static ADEL-FL pipeline solves Problem 2 once, offline, against a fixed
population view. When availability churn shifts the reachable population
mid-run (fewer devices than the planned cohort, a different compute-rate
spread), the Lemma-3 feasibility construction the schedule was solved under
no longer describes the rounds actually being executed: with a smaller
cohort ``U`` the layer-1 zero-contributor bound ``p_t^1 = Q(L, T_t/m)^U``
grows, and the bias-corrected aggregation pays for it in variance.

:class:`Replanner` closes the loop online:

* a **trigger policy** (:class:`ReplanConfig`) decides *when* to re-solve —
  ``never`` (the static baseline), ``every-k`` rounds, or ``drift`` when the
  reachable-device count moves past a relative threshold since the last
  (re-)plan;
* the **remaining-horizon problem** — rounds ``R - t``, budget
  ``T_max - elapsed``, the learning-rate tail, and a population view whose
  ``(U, P, B)`` are re-estimated from the currently-reachable fleet
  (:meth:`repro.fleet.engine.FleetCohortSource.replan_view`, backed by the
  availability models' expected-reachable estimator) — is solved by
  **warm-starting** :func:`repro.core.scheduler.solve_adam` from the tail of
  the incumbent schedule (:func:`repro.core.scheduler.invert_schedule`), so
  a mid-run re-solve costs a few hundred Adam steps instead of 3000;
* the re-solved tail is **spliced** into the policy's full-length schedule
  (consumed rounds keep their historical deadlines), preserving the
  nonincreasing-by-construction / budget-exact / ``p_t^1 <= 0.2`` Lemma-3
  feasibility guarantees for the tail.

The runtime hook lives in :meth:`repro.fl.runtime.RoundRuntime.run`, so
every execution backend (dense / chunked / shard_map) and both front-ends
(``run_federated`` / ``run_fleet``) re-plan identically; each re-solve is
recorded in ``History.replans``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

# NOTE: .scheduler (and with it jax/scipy) is imported lazily inside
# Replanner.replan so that light-weight consumers — repro.configs.base
# embeds ReplanConfig in FleetConfig — can import this module without
# initializing jax.
from .types import AnalysisConfig, Schedule

__all__ = ["TRIGGERS", "ReplanConfig", "ReplanEvent", "Replanner",
           "make_replan", "remaining_horizon"]

TRIGGERS = ("never", "every-k", "drift")


@dataclasses.dataclass(frozen=True)
class ReplanConfig:
    """When and how to re-solve the remaining-horizon Problem 2.

    ``trigger``: ``never`` | ``every-k`` | ``drift``. ``every`` is the
    every-k period; ``drift_threshold`` the relative reachable-count change
    (vs the last plan) that trips the drift trigger. ``steps`` bounds the
    warm-started Adam re-solve. Re-solving a tail shorter than
    ``min_rounds_left`` rounds is skipped (nothing left to re-allocate).
    """

    trigger: str = "never"
    every: int = 4
    drift_threshold: float = 0.25
    steps: int = 300
    min_rounds_left: int = 2

    def __post_init__(self):
        if self.trigger not in TRIGGERS:
            raise ValueError(f"unknown replan trigger {self.trigger!r}; "
                             f"known: {TRIGGERS}")

    @property
    def active(self) -> bool:
        return self.trigger != "never"


def make_replan(spec) -> Optional[ReplanConfig]:
    """Normalize ``None`` / trigger-name string / ReplanConfig."""
    if spec is None or isinstance(spec, ReplanConfig):
        return spec
    if isinstance(spec, str):
        return ReplanConfig(trigger=spec)
    raise TypeError(f"replan must be None, a trigger name, or ReplanConfig; "
                    f"got {type(spec).__name__}")


@dataclasses.dataclass
class ReplanEvent:
    """One mid-run re-solve, as recorded in ``History.replans``."""

    round: int                 # round index t the re-plan took effect at
    reachable: int             # reachable-device count that triggered it
    U_est: int                 # re-estimated plannable cohort size
    budget_left: float         # T_max - elapsed at re-plan time
    T_tail: list               # re-solved deadlines for rounds t..R-1
    m: float                   # re-solved global batch-scaling parameter
    objective: float           # Theorem-1 bound of the re-solved tail
    steps: int                 # warm-start Adam steps spent
    # deadline budget credited back from rounds skipped (empty cohort)
    # since the previous (re-)plan — already part of budget_left
    skipped_credit: float = 0.0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def remaining_horizon(cfg: AnalysisConfig, t: int, budget_left: float,
                      eta_tail: np.ndarray) -> AnalysisConfig:
    """``cfg`` restricted to rounds ``t..R-1`` with the un-spent budget."""
    u_tail = None if cfg.U_round is None else cfg.U_round[t:]
    return dataclasses.replace(cfg, R=cfg.R - t, T_max=float(budget_left),
                               eta=np.asarray(eta_tail, np.float32),
                               U_round=u_tail)


class Replanner:
    """Trigger bookkeeping + warm-start re-solve + schedule splice.

    Owned by :meth:`repro.fl.runtime.RoundRuntime.run`; stateless apart
    from the reachable-count reference of the last (re-)plan and the
    deadline budget credited back from skipped empty rounds
    (:meth:`note_skip` — a pending credit forces the next ``should_replan``
    to fire so the stranded budget is re-allocated immediately). The
    policy must be schedule-driven (ADEL) — re-planning mutates
    ``policy.schedule`` in place so the next ``policy.round(t)`` reads the
    new tail.
    """

    def __init__(self, cfg: ReplanConfig, policy, rounds: int,
                 eta: np.ndarray, s_max: Optional[int] = None,
                 rate_max: Optional[float] = None):
        if not hasattr(policy, "schedule"):
            raise ValueError(
                f"re-planning requires a schedule-driven policy (adel); "
                f"got {getattr(policy, 'name', type(policy).__name__)!r}")
        self.cfg = cfg
        self.policy = policy
        self.rounds = int(rounds)
        self.eta = np.asarray(eta, np.float32)
        # executable-batch bound: the runtime's minibatch pad width was
        # probed against the INITIAL schedule, so a re-solve must keep the
        # largest plannable batch (~ m * max P_u) within it or the executor
        # would silently clip batches and break the B_t variance accounting
        self.s_max = s_max
        self.rate_max = None if rate_max is None else float(rate_max)
        self.ref_reachable: Optional[int] = None
        self.events: list[ReplanEvent] = []
        # budget credited back from skipped empty rounds since the last
        # (re-)plan; a pending credit forces a re-solve at the next
        # executed round so the stranded deadlines are re-allocated
        self.skipped_credit: float = 0.0
        self._skip_pending: bool = False

    # ------------------------------------------------------------------
    def note_skip(self, t: int) -> float:
        """Round ``t`` never started (empty cohort): credit its un-spent
        deadline back.

        The skipped round's historical deadline is zeroed in the spliced
        schedule — it spent nothing, so the consumed-rounds record must not
        claim its budget — and a re-solve is forced at the next executed
        round, whose ``budget_left = T_max - elapsed`` then sees the true
        remaining budget including the credit. Returns the credited
        deadline.
        """
        sch: Schedule = self.policy.schedule
        T = np.asarray(sch.T, np.float64).copy()
        if not 0 <= t < len(T):
            return 0.0
        credited = float(T[t])
        T[t] = 0.0
        self.policy.schedule = dataclasses.replace(sch, T=T)
        self.skipped_credit += credited
        self._skip_pending = True
        return credited

    # ------------------------------------------------------------------
    def should_replan(self, t: int, reachable: int) -> bool:
        if self.ref_reachable is None:
            self.ref_reachable = int(reachable)   # round-0 plan reference
            return False
        if t == 0 or self.rounds - t < max(self.cfg.min_rounds_left, 2):
            return False
        if self._skip_pending:
            # stranded deadline budget from skipped rounds: re-allocate it
            # now, whatever the configured trigger cadence says
            return True
        if self.cfg.trigger == "every-k":
            return t % max(self.cfg.every, 1) == 0
        if self.cfg.trigger == "drift":
            rel = abs(reachable - self.ref_reachable) / max(
                self.ref_reachable, 1)
            return rel > self.cfg.drift_threshold
        return False

    # ------------------------------------------------------------------
    def replan(self, t: int, budget_left: float, reachable: int,
               view: Optional[AnalysisConfig] = None) -> ReplanEvent:
        """Re-solve rounds ``t..R-1`` and splice the tail into the policy.

        ``view`` is the remaining-horizon AnalysisConfig (re-estimated from
        the reachable population by the cohort source); when ``None`` the
        policy's own planning config is restricted to the remaining horizon
        (static populations: same constants, fresh budget accounting).
        """
        from .scheduler import invert_schedule, solve_adam

        old: Schedule = self.policy.schedule
        budget_left = max(float(budget_left), 1e-6)
        if view is None:
            view = remaining_horizon(self.policy.cfg, t, budget_left,
                                     self.eta[t:self.rounds])
        # bound against the FASTEST device the run can plan for (the
        # population-wide rate when the source exposes it — the view's
        # quantile-picked P can under-represent offline fast devices),
        # matching the best-case device the s_max probe assumed
        P_fast = max(float(np.max(view.P)),
                     self.rate_max if self.rate_max is not None else 0.0)
        m_max = (None if self.s_max is None
                 else float(self.s_max) / P_fast)
        # warm start from the incumbent tail, rescaled onto the remaining
        # budget by the parameterization itself
        theta0 = invert_schedule(view, old.T[t:], old.m, m_max=m_max)
        sch = solve_adam(view, steps=self.cfg.steps, theta0=theta0,
                         m_max=m_max)
        # splice: consumed rounds keep their historical record
        T = np.concatenate([np.asarray(old.T[:t], np.float64), sch.T])
        p1 = np.concatenate([np.asarray(old.p1[:t], np.float64), sch.p1])
        self.policy.schedule = Schedule(T=T, m=sch.m, objective=sch.objective,
                                        p1=p1, solver=f"{sch.solver}-replan")
        self.ref_reachable = int(reachable)
        credit, self.skipped_credit = self.skipped_credit, 0.0
        self._skip_pending = False
        ev = ReplanEvent(round=t, reachable=int(reachable), U_est=int(view.U),
                         budget_left=float(budget_left),
                         T_tail=[float(x) for x in sch.T],
                         m=float(sch.m), objective=float(sch.objective),
                         steps=int(self.cfg.steps),
                         skipped_credit=float(credit))
        self.events.append(ev)
        return ev
