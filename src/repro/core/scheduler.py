"""Problem-2 solver: jointly optimize per-round deadlines {T_t^d} and the
global batch-scaling parameter m.

Two solver paths:

* ``solve_trust_region`` — scipy ``trust-constr`` exactly as the paper
  (Section III-C, [48]), with JAX-supplied exact gradients, linear
  constraints for the time budget + monotonicity, and a nonlinear
  constraint for p_t^1 < 0.2.
* ``solve_adam`` — a pure-JAX projected solver on an unconstrained
  parameterization (nonincreasing-by-construction deadlines that use the
  entire budget; penalties for the remaining constraints). Fast, jittable,
  and used as the default inside the training loop.

Both return a :class:`repro.core.types.Schedule`.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .cost import objective_and_penalty, p1_round, theorem1_bound
from .gamma import q_inv
from .types import AnalysisConfig, Schedule

__all__ = ["solve_adam", "solve_trust_region", "solve", "solve_rounds",
           "constant_schedule", "invert_schedule"]


# ---------------------------------------------------------------------------
# Parameterization: theta in R^{R+1} -> (T, m) FEASIBLE BY CONSTRUCTION:
#   * T nonincreasing, sum T = T_max (uses the full budget)
#   * p_t^1 = Q(L, T_t/m)^U <= p1_cap for every t, via the hard floor
#     T_t >= m * x_min with x_min = q_inv(L, p1_cap^(1/U))  (Lemma 3 validity)
#   * m in (m_min, m_cap], m_cap = T_max / (R * x_min) so the floor fits
# ---------------------------------------------------------------------------

def _x_min(cfg: AnalysisConfig, p1_cap: float = 0.2,
           margin: float = 0.9) -> float:
    # Lemma-3 validity floor x >= q_inv(L, cap^(1/U)). Under a per-round
    # availability forecast (cfg.U_round) the SMALLEST expected cohort
    # binds: fewer contributors need deeper per-client completion to keep
    # the all-miss probability Q(L, x)^U below the cap, so that round's
    # floor is the largest — applying it to every round keeps the whole
    # nonincreasing-by-construction schedule feasible.
    U_eff = cfg.U if cfg.U_round is None else float(np.min(cfg.U_round))
    return q_inv(cfg.L, (margin * p1_cap) ** (1.0 / U_eff))


def _m_cap(cfg: AnalysisConfig, x_min: float, m_max: float | None) -> float:
    """Upper bound for m: the structural floor-fits-budget cap, optionally
    tightened by an executable-batch bound (``m_max`` — e.g. the runtime's
    probed ``s_max`` divided by the fastest plannable rate, so a mid-run
    re-solve never plans batches the executor would silently clip)."""
    cap = cfg.T_max / (cfg.R * max(x_min, 1e-9)) if x_min > 0 else np.inf
    if m_max is not None:
        cap = min(cap, float(m_max))
    return cap


def _theta_to_Tm(theta: jnp.ndarray, cfg: AnalysisConfig, m_min: float = 1.0,
                 x_min: float = 0.0, m_max: float | None = None):
    # m in (m_min, m_cap]: sigmoid-bounded so R * m * x_min <= T_max
    m_cap = _m_cap(cfg, x_min, m_max)
    if np.isfinite(m_cap) and m_cap > m_min:
        m = m_min + (m_cap - m_min) * jax.nn.sigmoid(theta[cfg.R])
    else:  # budget too tight for the cap at m_min: pin m (degenerate corner)
        m = jnp.float32(m_min)
    # Per-round feasibility floor. If the instance is infeasible even at
    # m_min (m * x_min > T_max / R), fall back to the uniform allocation —
    # the schedule maximizing the binding last-round deadline.
    floor = jnp.minimum(m * x_min, cfg.T_max / cfg.R)
    e = jax.nn.softplus(theta[: cfg.R])              # (R,) >= 0 increments
    b = jnp.cumsum(e[::-1])[::-1]                    # nonincreasing, positive
    extra = cfg.T_max - cfg.R * floor                # budget above the floor
    T = floor + extra * b / jnp.maximum(b.sum(), 1e-9)
    return T, m


def _invert_m(m: float, m_min: float, m_cap: float) -> tuple[np.ndarray, float]:
    """theta_m reproducing ``m`` under the sigmoid bound of
    :func:`_theta_to_Tm`, plus the m the parameterization will actually
    realize (``m`` clipped into ``(m_min, m_cap]``; pinned at ``m_min``
    in the degenerate budget-too-tight corner)."""
    if np.isfinite(m_cap) and m_cap > m_min:
        frac = np.clip((m - m_min) / (m_cap - m_min), 1e-4, 1 - 1e-4)
        theta_m = np.asarray([np.log(frac / (1 - frac))], np.float32)
        m_eff = m_min + (m_cap - m_min) * float(frac)
    else:
        theta_m = np.zeros((1,), np.float32)
        m_eff = m_min
    return theta_m, m_eff


def _init_theta(cfg: AnalysisConfig, m0: float, m_min: float = 1.0,
                x_min: float = 0.0, m_max: float | None = None) -> jnp.ndarray:
    # start from the naive uniform allocation T_t = T_max / R and m = m0
    theta_T = jnp.full((cfg.R,), np.log(np.expm1(1.0)), jnp.float32)
    theta_m, _ = _invert_m(m0, m_min, _m_cap(cfg, x_min, m_max))
    return jnp.concatenate([theta_T, jnp.asarray(theta_m)])


def _default_m0(cfg: AnalysisConfig) -> float:
    """Heuristic initial m: aim the per-round Poisson rate T_t/m at ~L so the
    average client completes the full depth (x = T/m ~= L keeps p_t^1 tiny)."""
    return max(1.5, (cfg.T_max / cfg.R) / max(cfg.L, 1))


def _default_m_min(cfg: AnalysisConfig) -> float:
    """Smallest m keeping every batch size S_t^u = m P_u (1 - B_u/T) >= ~2
    (so the B_t denominator m P_u frac - 1 stays positive, A2/B3)."""
    return 2.0 / float(cfg.P.min())


def invert_schedule(cfg: AnalysisConfig, T, m: float, *,
                    m_min: float | None = None,
                    m_max: float | None = None) -> jnp.ndarray:
    """Map a target ``(T, m)`` onto the solver's theta parameterization.

    The returned theta reproduces ``T`` (rescaled onto ``cfg.T_max`` — only
    the ratios of ``T_t`` above the feasibility floor matter) and ``m``
    (clipped into ``(m_min, m_cap]``) under :func:`_theta_to_Tm`, so a
    mid-run re-solve can warm-start ``solve_adam`` from the tail of a
    previous schedule instead of the uniform initialization.
    """
    m_min = _default_m_min(cfg) if m_min is None else m_min
    x_min = _x_min(cfg)
    T = np.asarray(T, np.float64)
    assert T.shape == (cfg.R,), (T.shape, cfg.R)
    theta_m, m_eff = _invert_m(m, m_min, _m_cap(cfg, x_min, m_max))
    # T component: T = floor + extra * b / sum(b) with b the reversed cumsum
    # of e = softplus(theta). Only the ratios of b matter, so normalize the
    # above-floor mass to sum R (keeps e, theta O(1) for Adam).
    floor = min(m_eff * x_min, cfg.T_max / cfg.R)
    b = np.maximum(T - floor, 1e-6)
    b = np.maximum.accumulate(b[::-1])[::-1]        # enforce nonincreasing T
    b = b / b.sum() * cfg.R
    e = np.maximum(b - np.concatenate([b[1:], [0.0]]), 1e-4)
    theta_T = np.log(np.expm1(e)).astype(np.float32)
    return jnp.concatenate([jnp.asarray(theta_T), jnp.asarray(theta_m)])


def solve_adam(cfg: AnalysisConfig, *, steps: int = 3000, lr: float = 3e-2,
               m0: float | None = None, m_min: float | None = None,
               seed: int = 0, theta0: jnp.ndarray | None = None,
               m_max: float | None = None) -> Schedule:
    m0 = _default_m0(cfg) if m0 is None else m0
    m_min = _default_m_min(cfg) if m_min is None else m_min
    x_min = _x_min(cfg)
    theta = (_init_theta(cfg, m0, m_min, x_min, m_max) if theta0 is None
             else jnp.asarray(theta0, jnp.float32))

    def loss_fn(th):
        T, m = _theta_to_Tm(th, cfg, m_min, x_min, m_max)
        val, (obj, p1) = objective_and_penalty(T, m, cfg)
        return val, (obj, p1)

    grad_fn = jax.jit(jax.value_and_grad(loss_fn, has_aux=True))

    # Adam state
    mu = jnp.zeros_like(theta)
    nu = jnp.zeros_like(theta)
    b1, b2, eps = 0.9, 0.999, 1e-8

    @jax.jit
    def step(i, theta, mu, nu):
        (val, aux), g = grad_fn(theta)
        mu2 = b1 * mu + (1 - b1) * g
        nu2 = b2 * nu + (1 - b2) * g * g
        mhat = mu2 / (1 - b1 ** (i + 1))
        nhat = nu2 / (1 - b2 ** (i + 1))
        theta2 = theta - lr * mhat / (jnp.sqrt(nhat) + eps)
        return theta2, mu2, nu2, val, aux

    best = (np.inf, theta)
    for i in range(steps):
        theta, mu, nu, val, aux = step(i, theta, mu, nu)
        v = float(val)
        if v < best[0]:
            best = (v, theta)
    theta = best[1]
    T, m = _theta_to_Tm(theta, cfg, m_min, x_min, m_max)
    T = np.asarray(T, np.float64)
    m = float(m)
    p1 = np.asarray(p1_round(jnp.asarray(T, jnp.float32), jnp.float32(m), cfg))
    obj = float(theorem1_bound(jnp.asarray(T, jnp.float32), jnp.float32(m), cfg))
    return Schedule(T=T, m=m, objective=obj, p1=p1, solver="adam")


def solve_trust_region(cfg: AnalysisConfig, *, m0: float | None = None,
                       m_min: float | None = None, maxiter: int = 300) -> Schedule:
    """The paper's solver: scipy trust-constr on x = [T_1..T_R, m]."""
    from scipy.optimize import LinearConstraint, NonlinearConstraint, minimize

    m0 = _default_m0(cfg) if m0 is None else m0
    m_min = _default_m_min(cfg) if m_min is None else m_min
    R = cfg.R
    Bmax = float(cfg.B_eff.max())

    def unpack(x):
        return jnp.asarray(x[:R], jnp.float32), jnp.float32(x[R])

    @jax.jit
    def f_and_g(x):
        def f(x):
            T, m = x[:R], x[R]
            val, _ = objective_and_penalty(T, m, cfg, penalty_weight=0.0)
            return val
        return jax.value_and_grad(f)(x)

    def fun(x):
        v, g = f_and_g(jnp.asarray(x, jnp.float32))
        return float(v), np.asarray(g, np.float64)

    @jax.jit
    def p1_fn(x):
        T, m = unpack(x)
        return p1_round(T, m, cfg)

    p1_jac = jax.jit(jax.jacobian(lambda x: p1_fn(x)))

    # sum T <= T_max  and  T_{t+1} - T_t <= 0
    A_sum = np.zeros((1, R + 1)); A_sum[0, :R] = 1.0
    A_mono = np.zeros((R - 1, R + 1))
    for t in range(R - 1):
        A_mono[t, t + 1] = 1.0
        A_mono[t, t] = -1.0
    lc = [LinearConstraint(A_sum, -np.inf, cfg.T_max),
          LinearConstraint(A_mono, -np.inf, 0.0)]
    nc = NonlinearConstraint(
        lambda x: np.asarray(p1_fn(jnp.asarray(x, jnp.float32)), np.float64),
        -np.inf, 0.2 - 1e-3,
        jac=lambda x: np.asarray(p1_jac(jnp.asarray(x, jnp.float32)), np.float64))

    x0 = np.concatenate([np.full(R, cfg.T_max / R), [m0]])
    lb = np.concatenate([np.full(R, Bmax * 1.05 + 1e-6), [m_min]])
    ub = np.concatenate([np.full(R, cfg.T_max), [np.inf]])
    import warnings
    with warnings.catch_warnings():
        warnings.filterwarnings("ignore", message="delta_grad == 0.0")
        res = minimize(fun, x0, jac=True, method="trust-constr",
                       constraints=lc + [nc], bounds=list(zip(lb, ub)),
                       options={"maxiter": maxiter, "verbose": 0})
    T = np.maximum.accumulate(res.x[:R][::-1])[::-1]  # snap tiny monotonicity violations
    m = float(res.x[R])
    p1 = np.asarray(p1_round(jnp.asarray(T, jnp.float32), jnp.float32(m), cfg))
    obj = float(theorem1_bound(jnp.asarray(T, jnp.float32), jnp.float32(m), cfg))
    return Schedule(T=T, m=m, objective=obj, p1=p1, solver="trust-constr")


def constant_schedule(cfg: AnalysisConfig, *, m: float | None = None) -> Schedule:
    """The naive baseline allocation: T_t = T_max/R with a feasible fixed m
    (used by Drop-Stragglers / SALF baselines)."""
    T = np.full((cfg.R,), cfg.T_max / cfg.R, np.float64)
    if m is None:
        m = _default_m0(cfg)
    p1 = np.asarray(p1_round(jnp.asarray(T, jnp.float32), jnp.float32(m), cfg))
    obj = float(theorem1_bound(jnp.asarray(T, jnp.float32), jnp.float32(m), cfg))
    return Schedule(T=T, m=float(m), objective=obj, p1=p1, solver="constant")


def solve_rounds(cfg: AnalysisConfig, method: str = "adam",
                 r_grid: "Sequence[int] | None" = None,
                 **kw) -> tuple[Schedule, AnalysisConfig]:
    """Beyond-paper extension (paper §III-D): jointly optimize the NUMBER of
    global rounds R alongside {T_t^d} and m.

    The paper notes this mixed-integer extension "could be formulated ... or
    tackled with adaptive scheduling heuristics". Since the inner problem is
    cheap, we solve it exactly on a grid of R values (the outer integer
    variable) and keep the R minimizing the Theorem-1 bound. The learning-
    rate schedule is re-generated per R with the same eta_1 (inverse decay).

    Returns (best schedule, the AnalysisConfig at the chosen R).
    """
    import dataclasses

    if r_grid is None:
        base = cfg.R
        r_grid = sorted({max(2, r) for r in
                         (base // 4, base // 2, (3 * base) // 4, base,
                          (3 * base) // 2, 2 * base)})
    eta1 = float(cfg.eta[0])
    best = None
    for r in r_grid:
        t = np.arange(1, r + 1, dtype=np.float32)
        eta = (eta1 * 2.0) / (1.0 + t)       # same inverse-decay family
        cfg_r = dataclasses.replace(cfg, R=int(r), eta=eta)
        sch = solve(cfg_r, method, **kw)
        if best is None or sch.objective < best[0].objective:
            best = (sch, cfg_r)
    return best


def solve(cfg: AnalysisConfig, method: str = "trust-constr", **kw) -> Schedule:
    if method in ("trust-constr", "trust_region", "paper"):
        return solve_trust_region(cfg, **kw)
    if method == "adam":
        return solve_adam(cfg, **kw)
    if method == "constant":
        return constant_schedule(cfg, **kw)
    raise ValueError(f"unknown solver {method!r}")
