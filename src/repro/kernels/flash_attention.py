"""Pallas TPU flash attention (GQA, causal, optional sliding window).

TPU-native adaptation: q/k/v blocks are tiled for VMEM with MXU-aligned
block shapes (multiples of 128 on the matmul dims); the online-softmax
accumulators (m, l, acc) live in VMEM scratch and persist across the
innermost (arbitrary-semantics) kv-block grid dimension. Causal + window
masking is applied per block, and fully-masked kv blocks are skipped via
the grid bound (kv blocks beyond the causal frontier are never visited).

Layout: q (B, H, Sq, hd); k, v (B, KV, Sk, hd); H = KV * G.
Grid: (B * H, nq, nk) — one q block row per (batch, head), scanning kv.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

__all__ = ["flash_attention"]

_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: int, bq: int, bk: int,
            nk: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)               # (bq, hd)
    k = k_ref[0].astype(jnp.float32)               # (bk, hd)
    v = v_ref[0].astype(jnp.float32)               # (bk, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_ref[...]                            # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)                # rescale old accumulators
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    l_new = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """q: (B, H, Sq, hd); k, v: (B, KV, Sk, hd) -> (B, H, Sq, hd)."""
    B, H, Sq, hd = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    nq, nk = Sq // bq, Sk // bk
    grid = (B * H, nq, nk)

    def qmap(h, i, j):
        return (h, i, 0)

    def kvmap(h, i, j):
        return (h // G, j, 0)   # flat (B*KV) leading axis via reshape below

    # reshape to (B*H, Sq, hd) / (B*KV, Sk, hd) so index maps stay 1D
    q3 = q.reshape(B * H, Sq, hd)
    k3 = k.reshape(B * KV, Sk, hd)
    v3 = v.reshape(B * KV, Sk, hd)

    def kvmap3(h, i, j):
        b, hh = h // H, h % H
        return (b * KV + hh // G, j, 0)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=hd ** -0.5, causal=causal,
                          window=window, bq=bq, bk=bk, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), qmap),
            pl.BlockSpec((1, bk, hd), kvmap3),
            pl.BlockSpec((1, bk, hd), kvmap3),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), qmap),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),      # m
            pltpu.VMEM((bq, 1), jnp.float32),      # l
            pltpu.VMEM((bq, hd), jnp.float32),     # acc
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q3, k3, v3)
    return out.reshape(B, H, Sq, hd)
