"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["flash_attention_ref", "ssd_scan_ref", "adel_agg_ref",
           "adel_agg_q8_ref"]


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True, window: int = 0) -> jnp.ndarray:
    """GQA attention oracle. q: (B, H, Sq, hd); k, v: (B, KV, Sk, hd)."""
    B, H, Sq, hd = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    qr = q.reshape(B, KV, G, Sq, hd).astype(jnp.float32)
    scores = jnp.einsum("bkgsd,bktd->bkgst", qr,
                        k.astype(jnp.float32)) * hd ** -0.5
    qpos = jnp.arange(Sq)
    kpos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > qpos[:, None] - window
    scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,bktd->bkgsd", probs, v.astype(jnp.float32))
    return out.reshape(B, H, Sq, hd).astype(q.dtype)


def ssd_scan_ref(x, dt, A, b, c):
    """Sequential SSD oracle (same semantics as models.ssm.ssd_reference).

    x: (B, S, H, P); dt: (B, S, H); A: (H,); b, c: (B, S, N).
    Returns y: (B, S, H, P).
    """
    from repro.models.ssm import ssd_reference
    y, _ = ssd_reference(x, dt, A, b, c)
    return y


def adel_agg_ref(grads: jnp.ndarray, coeff: jnp.ndarray) -> jnp.ndarray:
    """ADEL layer-wise masked aggregation oracle.

    grads: (U, L, F) per-client per-layer flattened gradients;
    coeff: (U, L) per-(client, layer) aggregation coefficients
    (mask / count / (1 - p), see core.aggregation.layer_coefficients).
    Returns (L, F) = sum_u coeff[u, l] * grads[u, l, :].
    """
    return jnp.einsum("ul,ulf->lf", coeff.astype(jnp.float32),
                      grads.astype(jnp.float32)).astype(grads.dtype)


def adel_agg_q8_ref(q: jnp.ndarray, scales: jnp.ndarray,
                    coeff: jnp.ndarray) -> jnp.ndarray:
    """Oracle for the fused int8 dequant + Eq. 5 weight + accumulate kernel.

    q: (U, L, F) int8; scales, coeff: (U, L).
    Returns (L, F) float32 = sum_u coeff[u, l] * scales[u, l] * q[u, l, :].
    """
    w = coeff.astype(jnp.float32) * scales.astype(jnp.float32)
    return jnp.einsum("ul,ulf->lf", w, q.astype(jnp.float32))
