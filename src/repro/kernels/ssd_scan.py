"""Pallas TPU Mamba2 SSD chunk-scan kernel.

TPU adaptation of the SSD algorithm (arXiv:2405.21060 §6): the sequence is
tiled into chunks of Q tokens held in VMEM; the within-chunk "dual" term is
two MXU matmuls (C·Bᵀ masked by the decay kernel, then ·X), and the
cross-chunk recurrence carries the (N x P) state in VMEM scratch across the
innermost (arbitrary-semantics) chunk grid dimension — the TPU analogue of
the paper's inter-chunk scan.

Inputs are pre-scaled by the caller (ops.py): xdt = x * dt and
la = -softplus(A_log) * dt, so the kernel is pure chunked linear algebra.

Layout: xdt (BH, S, P); la (BH, S); b, c (BH, S, N) (already expanded per
head-group). Grid: (BH, n_chunks).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

__all__ = ["ssd_scan"]


def _kernel(xdt_ref, la_ref, b_ref, c_ref, y_ref, state_ref, *, Q: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    xdt = xdt_ref[0].astype(jnp.float32)           # (Q, P)
    la = la_ref[0].astype(jnp.float32)             # (Q,)
    b = b_ref[0].astype(jnp.float32)               # (Q, N)
    c = c_ref[0].astype(jnp.float32)               # (Q, N)

    cum = jnp.cumsum(la)                           # (Q,)
    tot = cum[-1]

    # within-chunk dual term: (C Bᵀ ⊙ L) X
    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    decay = cum[:, None] - cum[None, :]            # (Q, Q)
    i = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    j = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    Lmat = jnp.where(i >= j, jnp.exp(decay), 0.0)
    y_intra = jax.lax.dot_general(scores * Lmat, xdt,
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    # inter-chunk term from the carried state
    state = state_ref[...]                         # (N, P)
    y_inter = jnp.exp(cum)[:, None] * jax.lax.dot_general(
        c, state, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: exp(tot) * state + Bᵀ diag(exp(tot - cum)) X
    w = jnp.exp(tot - cum)[:, None]                # (Q, 1)
    upd = jax.lax.dot_general(b * w, xdt, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    state_ref[...] = jnp.exp(tot) * state + upd


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(xdt: jnp.ndarray, la: jnp.ndarray, b: jnp.ndarray,
             c: jnp.ndarray, *, chunk: int = 64,
             interpret: bool = False) -> jnp.ndarray:
    """xdt: (BH, S, P); la: (BH, S); b, c: (BH, S, N) -> y (BH, S, P)."""
    BH, S, P = xdt.shape
    N = b.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nC = S // Q
    grid = (BH, nC)

    def m3(h, ci):
        return (h, ci, 0)

    def m2(h, ci):
        return (h, ci)

    return pl.pallas_call(
        functools.partial(_kernel, Q=Q),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Q, P), m3),
            pl.BlockSpec((1, Q), m2),
            pl.BlockSpec((1, Q, N), m3),
            pl.BlockSpec((1, Q, N), m3),
        ],
        out_specs=pl.BlockSpec((1, Q, P), m3),
        out_shape=jax.ShapeDtypeStruct((BH, S, P), xdt.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(xdt, la, b, c)
