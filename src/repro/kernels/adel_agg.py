"""Pallas TPU kernel for ADEL-FL's layer-wise masked aggregation (Eq. 5).

The server-side hot loop of the paper: combine U clients' per-layer
gradients with per-(client, layer) coefficients

    out[l, f] = sum_u coeff[u, l] * grads[u, l, f]

i.e. an (U)-contraction batched over layers, tiled over the flattened
feature dim so each (layer, feature-block) tile is one VMEM-resident MXU
matvec. On the real mesh this runs on each shard's local client slice,
followed by a psum (see core.aggregation.aggregate_grads_local).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels._compat import CompilerParams

__all__ = ["adel_agg", "adel_agg_q8"]


def _kernel(g_ref, c_ref, o_ref):
    g = g_ref[:, 0, :].astype(jnp.float32)         # (U, bf)
    c = c_ref[...].astype(jnp.float32)             # (U, 1)
    o = jax.lax.dot_general(c, g, (((0,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (1, bf)
    o_ref[0] = o[0].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_f", "interpret"))
def adel_agg(grads: jnp.ndarray, coeff: jnp.ndarray, *, block_f: int = 512,
             interpret: bool = False) -> jnp.ndarray:
    """grads: (U, L, F); coeff: (U, L) -> (L, F).

    Arbitrary F is supported: the flattened feature dim is zero-padded up to
    a ``block_f`` multiple for the kernel grid and the output sliced back.
    """
    U, L, F = grads.shape
    bf = min(block_f, F)
    pad = (-F) % bf
    if pad:
        grads = jnp.pad(grads, ((0, 0), (0, 0), (0, pad)))
    Fp = F + pad
    grid = (L, Fp // bf)

    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((U, 1, bf), lambda l, f: (0, l, f)),
            pl.BlockSpec((U, 1), lambda l, f: (0, l)),
        ],
        out_specs=pl.BlockSpec((1, bf), lambda l, f: (l, f)),
        out_shape=jax.ShapeDtypeStruct((L, Fp), grads.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(grads, coeff)
    return out[:, :F] if pad else out


def _kernel_q8(q_ref, s_ref, c_ref, o_ref):
    g = q_ref[:, 0, :].astype(jnp.float32)         # (U, bf) dequant source
    # fold the Eq. 5 coefficient into the per-(client, layer) dequant scale
    # so dequantize + weight + accumulate is one f32 MXU matvec
    w = (c_ref[...] * s_ref[...]).astype(jnp.float32)            # (U, 1)
    o = jax.lax.dot_general(w, g, (((0,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (1, bf)
    o_ref[0] = o[0]


@functools.partial(jax.jit, static_argnames=("block_f", "interpret"))
def adel_agg_q8(q: jnp.ndarray, scales: jnp.ndarray, coeff: jnp.ndarray, *,
                block_f: int = 512, interpret: bool = False) -> jnp.ndarray:
    """Fused dequantize + Eq. 5 weight + accumulate over int8 payloads.

    q: (U, L, F) int8 symmetric-quantized client deltas;
    scales: (U, L) per-(client, layer) dequant scales (absmax / 127);
    coeff: (U, L) Eq. 5 aggregation coefficients.
    Returns (L, F) float32 = sum_u coeff[u, l] * scales[u, l] * q[u, l, :]
    — the reduction consumes the int8 wire format directly; the float32
    delta tree is never materialized per client.
    """
    U, L, F = q.shape
    bf = min(block_f, F)
    pad = (-F) % bf
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad)))
    Fp = F + pad
    grid = (L, Fp // bf)

    out = pl.pallas_call(
        _kernel_q8,
        grid=grid,
        in_specs=[
            pl.BlockSpec((U, 1, bf), lambda l, f: (0, l, f)),
            pl.BlockSpec((U, 1), lambda l, f: (0, l)),
            pl.BlockSpec((U, 1), lambda l, f: (0, l)),
        ],
        out_specs=pl.BlockSpec((1, bf), lambda l, f: (l, f)),
        out_shape=jax.ShapeDtypeStruct((L, Fp), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(q, scales.astype(jnp.float32), coeff.astype(jnp.float32))
    return out[:, :F] if pad else out
