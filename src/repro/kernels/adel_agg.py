"""Pallas TPU kernel for ADEL-FL's layer-wise masked aggregation (Eq. 5).

The server-side hot loop of the paper: combine U clients' per-layer
gradients with per-(client, layer) coefficients

    out[l, f] = sum_u coeff[u, l] * grads[u, l, f]

i.e. an (U)-contraction batched over layers, tiled over the flattened
feature dim so each (layer, feature-block) tile is one VMEM-resident MXU
matvec. On the real mesh this runs on each shard's local client slice,
followed by a psum (see core.aggregation.aggregate_grads_local).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels._compat import CompilerParams

__all__ = ["adel_agg"]


def _kernel(g_ref, c_ref, o_ref):
    g = g_ref[:, 0, :].astype(jnp.float32)         # (U, bf)
    c = c_ref[...].astype(jnp.float32)             # (U, 1)
    o = jax.lax.dot_general(c, g, (((0,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (1, bf)
    o_ref[0] = o[0].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_f", "interpret"))
def adel_agg(grads: jnp.ndarray, coeff: jnp.ndarray, *, block_f: int = 512,
             interpret: bool = False) -> jnp.ndarray:
    """grads: (U, L, F); coeff: (U, L) -> (L, F).

    Arbitrary F is supported: the flattened feature dim is zero-padded up to
    a ``block_f`` multiple for the kernel grid and the output sliced back.
    """
    U, L, F = grads.shape
    bf = min(block_f, F)
    pad = (-F) % bf
    if pad:
        grads = jnp.pad(grads, ((0, 0), (0, 0), (0, pad)))
    Fp = F + pad
    grid = (L, Fp // bf)

    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((U, 1, bf), lambda l, f: (0, l, f)),
            pl.BlockSpec((U, 1), lambda l, f: (0, l)),
        ],
        out_specs=pl.BlockSpec((1, bf), lambda l, f: (l, f)),
        out_shape=jax.ShapeDtypeStruct((L, Fp), grads.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(grads, coeff)
    return out[:, :F] if pad else out
