"""jit'd wrappers bridging model-layout tensors to the Pallas kernels.

``interpret`` defaults to True on CPU (this container) and False on TPU;
each wrapper reshapes from model layout to kernel layout and back, and is
drop-in compatible with the pure-jnp path it accelerates.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.kernels.adel_agg import adel_agg
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd_scan import ssd_scan

__all__ = ["gqa_flash", "ssd_chunked_pallas", "adel_aggregate_pallas",
           "default_interpret"]


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def gqa_flash(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
              causal: bool = True, window: int = 0,
              block_q: int = 128, block_k: int = 128,
              interpret: bool | None = None) -> jnp.ndarray:
    """Model layout (B, S, H, hd) / (B, S, KV, hd) -> (B, S, H, hd)."""
    interpret = default_interpret() if interpret is None else interpret
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = flash_attention(qt, kt, vt, causal=causal, window=window,
                          block_q=block_q, block_k=block_k,
                          interpret=interpret)
    return jnp.swapaxes(out, 1, 2)


def ssd_chunked_pallas(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                       b: jnp.ndarray, c: jnp.ndarray, *, chunk: int = 64,
                       interpret: bool | None = None) -> jnp.ndarray:
    """Model layout: x (B,S,H,P); dt (B,S,H); A (H,); b,c (B,S,N) -> y."""
    interpret = default_interpret() if interpret is None else interpret
    B, S, H, P = x.shape
    N = b.shape[-1]
    xdt = (x.astype(jnp.float32) * dt[..., None]).transpose(0, 2, 1, 3)
    xdt = xdt.reshape(B * H, S, P)
    la = (-A[None, None, :] * dt).transpose(0, 2, 1).reshape(B * H, S)
    bh = jnp.broadcast_to(b[:, None], (B, H, S, N)).reshape(B * H, S, N)
    ch = jnp.broadcast_to(c[:, None], (B, H, S, N)).reshape(B * H, S, N)
    y = ssd_scan(xdt.astype(jnp.float32), la.astype(jnp.float32),
                 bh.astype(jnp.float32), ch.astype(jnp.float32),
                 chunk=chunk, interpret=interpret)
    return y.reshape(B, H, S, P).transpose(0, 2, 1, 3).astype(x.dtype)


def adel_aggregate_pallas(grads, layer_ids_tree, mask, p, *,
                          bias_correct: bool = True,
                          coeffs=None,
                          interpret: bool | None = None):
    """Pallas-backed equivalent of core.aggregation.aggregate_grads for
    pytrees whose leaves carry a leading client axis U.

    Stacked-layer leaves (ids of shape (L,)) go through the adel_agg kernel
    on their flattened feature dim; scalar-id leaves use the (U,) matvec.

    ``coeffs`` (U, L) overrides the internally computed Eq. 5 coefficients —
    the temporal backend folds one client at a time (U = 1 slices) against
    coefficients derived from GLOBAL cohort counts, which per-slice masks
    cannot reproduce.
    """
    from repro.core.aggregation import layer_coefficients
    interpret = default_interpret() if interpret is None else interpret
    if coeffs is None:
        coeffs = layer_coefficients(mask, p, bias_correct=bias_correct)
    c = coeffs                                                  # (U, L)

    def agg_leaf(g, ids):
        ids = jnp.asarray(ids)
        U = g.shape[0]
        if ids.ndim == 0:
            w = c[:, ids]                          # (U,)
            return jnp.tensordot(w, g.astype(jnp.float32),
                                 axes=(0, 0)).astype(g.dtype)
        L = g.shape[1]
        F = 1
        for d in g.shape[2:]:
            F *= d
        flat = g.reshape(U, L, F)
        cl = jnp.take(c, ids, axis=1)              # (U, L)
        # adel_agg pads F to a block multiple internally
        out = adel_agg(flat, cl, interpret=interpret)
        return out.reshape(g.shape[1:]).astype(g.dtype)

    return jax.tree.map(agg_leaf, grads, layer_ids_tree)
