"""Pallas TPU kernels for the perf-critical compute hot-spots, each with a
pure-jnp oracle in ref.py and a model-layout wrapper in ops.py:

* flash_attention — GQA/causal/sliding-window online-softmax attention
  (prefill/train hot-spot of the dense/moe/vlm/hybrid archs).
* ssd_scan — Mamba2 SSD chunk scan with VMEM-carried state (ssm/hybrid).
* adel_agg — the paper's layer-wise masked aggregation (server hot loop).

Validated in interpret=True mode on CPU; compiled for TPU on real hardware.
"""
from repro.kernels.adel_agg import adel_agg
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd_scan import ssd_scan

__all__ = ["adel_agg", "flash_attention", "ssd_scan"]
