"""Cross-version Pallas TPU compat.

jax renamed ``pltpu.TPUCompilerParams`` (<= 0.4.x) to
``pltpu.CompilerParams`` (>= 0.5); resolve whichever the installed
release provides so the kernels run on both.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

__all__ = ["CompilerParams"]
