"""Clock-model ledger: predicted vs simulated vs measured round time.

ADEL-FL's Problem-2 solver prices every round with the exponential compute
model (Appendix A / Model Formulations B1-B3): user ``u`` finishes one
layer-gradient in ``Exp(S_u / P_u)`` time, so within the effective deadline
``T_t - B_u`` it completes ``z_u ~ Poisson(lambda_u)`` layers with
``lambda_u = P_u / S_u * (T_t - B_u)``. The ledger records, per executed
round, the three clocks that model implies and the two it cannot see:

* ``T_deadline``    — the planned deadline ``T_t`` (what the solver spent),
* ``sim_total``     — the simulated R1/R2 clock after the round,
* ``wall_round_s``  — measured host wall time of the round (monotonic).
  Under the prefetch pipeline (``ExecSpec.pipeline="prefetch"``) the
  round's host planning phases ran DURING the previous round's device
  step, so ``wall_round_s`` covers only consume + dispatch + device work;
  the hidden planning time lands in the ``prefetch_overlap_s`` counter
  (:mod:`repro.obs.timeline` renders both),
* ``pred_full_s``   — the model's expected FULL-depth completion time
  ``max_u (B_u + L * S_u / P_u)``: how long a synchronized-wait server
  would expect to wait for this cohort (the deadline's counterfactual),
* ``depth_pred`` vs ``depth_real`` — the model's expected completed
  backprop depth ``E[min(z_u, L)]`` against the depth of the round's
  actual straggler draw (``mask.sum(1)``), the drift statistic that
  quantifies how well the solver's cost model matches execution.

It also tabulates the deadline misses the delayed-gradient / async work
(ROADMAP item 2) needs: per round, how many clients finished all ``L``
layers, how many missed (and by how many layers at worst), and how many
contributed nothing at all — with the model's own ``p_t^1`` prediction
alongside the realized layer-1 outcome.

:func:`round_record` builds one ledger row inside the runtime (only when a
tracer is active); :func:`drift_summary` reduces the rows to run-level
drift statistics; :func:`ledger_rows` / :func:`phase_table` re-derive both
from a recorded JSONL event stream (``python -m repro.obs.timeline``).
Everything here is plain numpy — no jax, no runtime imports — so the
report/timeline tooling stays importable anywhere.
"""
from __future__ import annotations

import numpy as np

__all__ = ["expected_depth", "round_record", "ledger_rows", "phase_table",
           "drift_summary"]


def expected_depth(lam: np.ndarray, L: int) -> np.ndarray:
    """``E[min(z, L)]`` for ``z ~ Poisson(lam)``, elementwise.

    Uses ``E[min(z, L)] = sum_{j=0}^{L-1} P(z > j)`` with the Poisson pmf
    accumulated iteratively — exact, vectorized, and cheap for the layer
    counts models ship (L <= a few hundred).
    """
    lam = np.asarray(lam, np.float64)
    pmf = np.exp(-lam)                    # P(z = 0)
    cdf = pmf.copy()
    out = np.zeros_like(lam)
    for j in range(int(L)):
        out += 1.0 - np.minimum(cdf, 1.0)     # P(z > j)
        pmf = pmf * lam / float(j + 1)        # P(z = j+1)
        cdf = cdf + pmf
    return out


def round_record(*, t: int, plan, cfg, L: int, U_act: int, U_pad: int,
                 s_max: int, sim_total: float, wall_round_s: float,
                 wall_total_s: float, available=None, carry=None,
                 regions=None) -> dict:
    """One clock-model ledger row for executed round ``t`` (0-based).

    ``plan`` is the round's :class:`repro.core.baselines.RoundPlan`;
    ``cfg`` the planning view the policy used (the cohort view when the
    fleet re-derived one, else the policy's static config) — its ``P``/``B``
    describe the round's clients, which is what makes the model-side
    predictions computable. When the view's population does not line up
    with the executed cohort (defensive: custom sources), the prediction
    fields are omitted rather than fabricated.

    ``carry`` is the buffered (semi-async) backend's per-round carry stats
    (``ExecutionBackend.last_carry``): ``carried_in`` — buffered client
    contributions folded into THIS round's update, ``carried_out`` — still
    pending in the buffer after the round, ``carried_dropped`` — expired
    (``> max_age``) or ring-evicted, and ``stale`` — the staleness
    histogram ``{tau: count}`` of this round's folds. The columns land
    next to ``depth_real`` so the clock ledger shows where missed-deadline
    work went.

    ``regions`` is the hierarchical backend's per-round region census
    (``ExecutionBackend.last_regions``): ``regions`` — edge regions this
    round actually folded, ``region_max`` — widest region census,
    ``region_pad`` — the padded gather width each region executed at.
    """
    mask = np.asarray(plan.mask, np.float32)[:U_act]          # (U_act, L)
    S = np.asarray(plan.batch_sizes, np.float64)[:U_act]      # (U_act,)
    depth = mask.sum(axis=1)                                  # (U_act,)
    T_t = float(plan.elapsed)
    rec = {
        "t": int(t),
        "T_deadline": T_t,
        "sim_round": T_t,
        "sim_total": float(sim_total),
        "wall_round_s": round(float(wall_round_s), 6),
        "wall_total_s": round(float(wall_total_s), 6),
        "cohort": int(U_act),
        "padded": int(U_pad),
        "batch_real": int(np.minimum(S, float(s_max)).sum()),
        "batch_padded": int(U_pad * s_max),
        "depth_real": round(float(depth.mean()), 4),
        "full": int((depth >= L).sum()),
        "missed": int((depth < L).sum()),
        "zero_contrib": int((depth == 0).sum()),
        "worst_miss": int(L - depth.min()) if U_act else 0,
        "layer1_zero": bool(mask[:, 0].sum() == 0) if U_act else True,
    }
    if available is not None:
        rec["available"] = int(available)
    if carry is not None:
        rec["carried_in"] = int(carry.get("carried_in", 0))
        rec["carried_out"] = int(carry.get("carried_out", 0))
        rec["carried_dropped"] = int(carry.get("carried_dropped", 0))
        # JSON object keys are strings; normalize so round-tripped rows
        # and in-process rows aggregate identically
        rec["stale"] = {str(k): int(v)
                        for k, v in (carry.get("stale") or {}).items()}
    if regions is not None:
        rec["regions"] = int(regions.get("regions", 1))
        rec["region_max"] = int(regions.get("region_max", U_act))
        rec["region_pad"] = int(regions.get("region_pad", U_act))
    p = np.asarray(plan.p, np.float64)
    if p.size:
        rec["p1_pred"] = float(p[0])
    P = np.asarray(getattr(cfg, "P", ()), np.float64)
    B = np.asarray(getattr(cfg, "B", ()), np.float64)
    if P.shape == S.shape and B.shape == S.shape and T_t > 0:
        lam = P / np.maximum(S, 1.0) * np.maximum(T_t - B, 0.0)
        rec["depth_pred"] = round(float(expected_depth(lam, L).mean()), 4)
        full_s = B + L * S / np.maximum(P, 1e-9)
        rec["pred_full_s"] = round(float(full_s.max()), 4)
        rec["pred_full_mean_s"] = round(float(full_s.mean()), 4)
    return rec


def ledger_rows(records) -> list[dict]:
    """The ``kind="round"`` ledger rows of an event-record iterable."""
    return [r for r in records if r.get("kind") == "round"]


def phase_table(records) -> dict:
    """``{round: {phase: total_s}}`` over the span records of an event
    stream (round None — spans outside any round — keys as 0)."""
    out: dict[int, dict] = {}
    for r in records:
        if r.get("kind") != "span":
            continue
        rnd = int(r.get("round") or 0)
        row = out.setdefault(rnd, {})
        row[r["name"]] = row.get(r["name"], 0.0) + float(r["dur_s"])
    return out


def drift_summary(rows) -> dict:
    """Run-level drift statistics over the ledger rows.

    ``depth_drift_*``: realized minus model-predicted mean backprop depth —
    positive means clients got further than the exponential model priced,
    negative means the model was optimistic. ``wall_per_sim``: measured
    host-seconds per simulated clock unit (the exchange rate between the
    two clocks; steady means the simulation is a faithful relative clock).
    ``miss_rate`` / ``zero_rate``: fraction of client-rounds that missed
    full depth / contributed nothing. ``p1_pred_mean`` vs
    ``layer1_zero_rate``: the Lemma-1-style zero-contributor probability
    against its realized frequency.
    """
    rows = [r for r in rows if "T_deadline" in r]
    if not rows:
        return {}
    out: dict = {"rounds": len(rows)}
    drifts = [r["depth_real"] - r["depth_pred"] for r in rows
              if "depth_pred" in r]
    if drifts:
        out["depth_drift_mean"] = round(float(np.mean(drifts)), 4)
        out["depth_drift_max_abs"] = round(float(np.max(np.abs(drifts))), 4)
    walls = np.asarray([r["wall_round_s"] for r in rows], np.float64)
    sims = np.asarray([r["sim_round"] for r in rows], np.float64)
    ok = sims > 0
    if ok.any():
        per = walls[ok] / sims[ok]
        out["wall_per_sim_mean"] = round(float(per.mean()), 6)
        out["wall_per_sim_max"] = round(float(per.max()), 6)
    clients = sum(r["cohort"] for r in rows)
    if clients:
        out["miss_rate"] = round(sum(r["missed"] for r in rows) / clients, 4)
        out["zero_rate"] = round(
            sum(r["zero_contrib"] for r in rows) / clients, 4)
    p1 = [r["p1_pred"] for r in rows if "p1_pred" in r]
    if p1:
        out["p1_pred_mean"] = round(float(np.mean(p1)), 6)
        out["layer1_zero_rate"] = round(
            float(np.mean([r["layer1_zero"] for r in rows])), 4)
    preds = [r["pred_full_s"] for r in rows if "pred_full_s" in r]
    if preds:
        # how much simulated time the deadline saved vs synchronized wait
        out["deadline_vs_full_wait"] = round(
            float(sum(r["T_deadline"] for r in rows) / max(sum(preds),
                                                           1e-9)), 4)
    carried = [r for r in rows if "carried_in" in r]
    if carried:
        out["carried_in_total"] = int(sum(r["carried_in"] for r in carried))
        out["carried_dropped_total"] = int(
            sum(r.get("carried_dropped", 0) for r in carried))
        out["carried_peak"] = int(max(r["carried_out"] for r in carried))
        stale_n = stale_sum = 0
        for r in carried:
            for tau, n in (r.get("stale") or {}).items():
                stale_n += int(n)
                stale_sum += int(n) * float(tau)
        if stale_n:
            out["stale_mean"] = round(stale_sum / stale_n, 4)
    reg = [r for r in rows if "regions" in r]
    if reg:
        out["regions_max"] = int(max(r["regions"] for r in reg))
        # gathered client-slots per real client: how much padded work the
        # two-tier fold executed relative to a flat reduction
        out["region_pad_overhead"] = round(float(np.mean(
            [r["regions"] * r["region_pad"] / max(r["cohort"], 1)
             for r in reg])), 4)
    return out
