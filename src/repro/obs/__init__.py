"""``repro.obs`` — structured telemetry for the round runtime.

Dependency-free tracing (:mod:`repro.obs.trace`), the clock-model ledger
quantifying how well the Problem-2 cost model tracks execution
(:mod:`repro.obs.ledger`), one shared formatting path for verbose output
(:mod:`repro.obs.format`), and a terminal timeline renderer
(``python -m repro.obs.timeline events.jsonl``).

Instrumented producers take a single ``tracer=`` hook (default
:data:`NULL_TRACER`, zero overhead): :class:`repro.fl.runtime.RoundRuntime`
(and its ``run_federated`` / ``run_fleet`` / ``launch.train`` front-ends)
emits phase spans, counters, and one ledger event per executed round; the
:mod:`repro.fl.backends` execution backends emit ``local_train`` /
``aggregate`` spans and bytes-aggregated counters.
"""
from repro.obs.format import format_eval, format_replan
from repro.obs.ledger import (drift_summary, expected_depth, ledger_rows,
                              phase_table, round_record)
from repro.obs.trace import (NULL_TRACER, PHASES, JsonlSink, MemorySink,
                             NullTracer, Sink, Span, Tracer, make_tracer,
                             now, tree_bytes)

__all__ = [
    "now", "PHASES", "Sink", "MemorySink", "JsonlSink", "Span", "Tracer",
    "NullTracer", "NULL_TRACER", "make_tracer", "tree_bytes",
    "round_record", "ledger_rows", "phase_table", "drift_summary",
    "expected_depth", "format_eval", "format_replan",
]
