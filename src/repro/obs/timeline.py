"""Render a recorded telemetry stream in the terminal.

    PYTHONPATH=src python -m repro.obs.timeline events.jsonl [events2.jsonl ...]

Three sections per stream:

* **phase timeline** — per-round wall-seconds by phase (``cohort`` /
  ``replan`` / ``plan`` / ``stack`` / ``local_train`` / ``aggregate`` /
  ``eval`` / ``checkpoint``), i.e. where each round's host time actually
  went;
* **clock-model ledger** — per-round planned deadline ``T_t``, simulated
  clock, measured wall time, and the exponential model's predictions
  (full-depth completion time, expected backprop depth) against the round's
  realized straggler draw (:mod:`repro.obs.ledger` documents the columns);
* **stragglers / deadline misses** — per-round full/missed/zero-contributor
  counts with the worst miss depth, plus the run-level drift summary.

When the stream carries the backends' split payload counters
(``aggregate_bytes_logical`` / ``aggregate_bytes_wire``) a fourth section
shows per-round bytes on the wire versus the dense-float32 logical payload
and the resulting compression ratio.

Prefetch-pipelined runs (``ExecSpec.pipeline="prefetch"``) add a fifth
section from the round driver's pipeline counters: per-round H2D bytes of
the stacked batches (``h2d_bytes``), worker planning time hidden behind
the device step (``prefetch_overlap_s``), main-thread stalls on the
prefetch future (``dispatch_wait_s``), and the one-off AOT warm-up cost
(``warm_up_s``).
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.obs.ledger import drift_summary, ledger_rows, phase_table
from repro.obs.trace import PHASES

__all__ = ["BYTE_COUNTERS", "PIPELINE_COUNTERS", "bytes_table",
           "counter_table", "load_events", "render", "main"]


def load_events(path: str) -> list[dict]:
    """Parse a JSONL event file, skipping unparseable lines (a crashed run
    leaves a valid prefix; never let one torn line hide the rest)."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                out.append(json.loads(line))
            except json.JSONDecodeError:
                continue
    return out


def _table(headers: list[str], rows: list[list[str]]) -> str:
    widths = [max(len(h), *(len(r[i]) for r in rows)) if rows else len(h)
              for i, h in enumerate(headers)]
    fmt = "  ".join(f"{{:>{w}}}" for w in widths)
    lines = [fmt.format(*headers), fmt.format(*("-" * w for w in widths))]
    lines += [fmt.format(*r) for r in rows]
    return "\n".join(lines)


def _fmt_ms(s: float) -> str:
    return f"{1e3 * s:.1f}"


def _fmt_bytes(b: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}TiB"


BYTE_COUNTERS = ("aggregate_bytes_logical", "aggregate_bytes_wire")

# the prefetch round driver's counters (repro.fl.runtime): stacked-batch
# H2D bytes, worker planning time hidden behind the device step, main-
# thread stalls on the prefetch future, and the one-off AOT warm-up cost
PIPELINE_COUNTERS = ("h2d_bytes", "prefetch_overlap_s", "dispatch_wait_s",
                     "warm_up_s")


def counter_table(records: list[dict],
                  names: tuple) -> dict[int, dict[str, float]]:
    """Per-round totals of the named ``kind="count"`` records:
    ``{round: {counter_name: total}}`` (rounds are 1-based, as stamped by
    the runtime; counter-less streams give an empty dict)."""
    out: dict[int, dict[str, float]] = {}
    for r in records:
        if r.get("kind") != "count" or r.get("name") not in names:
            continue
        rnd = r.get("round")
        if rnd is None:
            continue
        row = out.setdefault(int(rnd), {})
        row[r["name"]] = row.get(r["name"], 0.0) + float(r.get("value", 0))
    return out


def bytes_table(records: list[dict]) -> dict[int, dict[str, float]]:
    """Per-round totals of the split aggregation payload counters."""
    return counter_table(records, BYTE_COUNTERS)


def render(records: list[dict], *, title: str = "") -> str:
    """Render one event stream's three sections as a string."""
    out = []
    if title:
        out.append(f"== {title} ==")

    phases = phase_table(records)
    seen = [p for p in PHASES
            if any(p in row for row in phases.values())]
    extra = sorted({name for row in phases.values() for name in row}
                   - set(seen))
    cols = seen + extra
    if phases:
        rows = []
        for rnd in sorted(phases):
            row = phases[rnd]
            rows.append([str(rnd)] + [(_fmt_ms(row[c]) if c in row else "—")
                                      for c in cols]
                        + [_fmt_ms(sum(row.values()))])
        out.append("\n-- phase timeline (ms of host wall time per round) --")
        out.append(_table(["round"] + cols + ["total"], rows))

    ledger = ledger_rows(records)
    if ledger:
        rows = []
        for r in ledger:
            rows.append([
                str(r.get("round", r.get("t", 0) + 1)),
                f"{r['T_deadline']:.3f}",
                f"{r['sim_total']:.2f}",
                f"{r['wall_round_s']:.3f}",
                (f"{r['pred_full_s']:.3f}" if "pred_full_s" in r else "—"),
                (f"{r['depth_pred']:.2f}" if "depth_pred" in r else "—"),
                f"{r['depth_real']:.2f}",
                (f"{r['p1_pred']:.4f}" if "p1_pred" in r else "—"),
            ])
        out.append("\n-- clock-model ledger "
                   "(deadline vs simulated vs wall vs predicted) --")
        out.append(_table(["round", "T_t", "sim", "wall_s", "pred_full",
                           "depth_pred", "depth_real", "p1_pred"], rows))

        # buffered (semi-async) runs add the carry-buffer columns: what
        # missed-deadline work was folded in / still pending / dropped;
        # hierarchical runs add the per-round edge-region census
        carried = any("carried_in" in r for r in ledger)
        has_regions = any("regions" in r for r in ledger)
        rows = []
        for r in ledger:
            row = [
                str(r.get("round", r.get("t", 0) + 1)),
                str(r.get("available", "—")),
                str(r["cohort"]),
                str(r["full"]),
                str(r["missed"]),
                str(r["zero_contrib"]),
                str(r["worst_miss"]),
                f"{r['batch_real']}/{r['batch_padded']}",
            ]
            if carried:
                stale = r.get("stale") or {}
                row += [
                    str(r.get("carried_in", "—")),
                    str(r.get("carried_out", "—")),
                    str(r.get("carried_dropped", "—")),
                    ",".join(f"{tau}:{n}" for tau, n in
                             sorted(stale.items(),
                                    key=lambda kv: int(kv[0]))) or "—",
                ]
            if has_regions:
                row += [
                    str(r.get("regions", "—")),
                    str(r.get("region_max", "—")),
                    str(r.get("region_pad", "—")),
                ]
            rows.append(row)
        headers = ["round", "avail", "cohort", "full", "missed", "zero",
                   "worst_miss", "batch real/pad"]
        if carried:
            headers += ["carry_in", "carry_out", "dropped", "stale tau:n"]
        if has_regions:
            headers += ["regions", "reg_max", "reg_pad"]
        out.append("\n-- stragglers / deadline misses --")
        out.append(_table(headers, rows))

        drift = drift_summary(ledger)
        if drift:
            out.append("\n-- drift summary --")
            out += [f"  {k:24s} {v}" for k, v in drift.items()]

    bt = bytes_table(records)
    if bt:
        rows = []
        tot_l = tot_w = 0.0
        for rnd in sorted(bt):
            row = bt[rnd]
            logical = row.get("aggregate_bytes_logical", 0.0)
            wire = row.get("aggregate_bytes_wire", 0.0)
            tot_l += logical
            tot_w += wire
            ratio = f"{logical / wire:.2f}x" if wire else "—"
            rows.append([str(rnd), _fmt_bytes(logical), _fmt_bytes(wire),
                         ratio])
        ratio = f"{tot_l / tot_w:.2f}x" if tot_w else "—"
        rows.append(["total", _fmt_bytes(tot_l), _fmt_bytes(tot_w), ratio])
        out.append("\n-- aggregation payload (logical f32 vs bytes on the "
                   "wire) --")
        out.append(_table(["round", "logical", "wire", "ratio"], rows))

    pt = counter_table(records, PIPELINE_COUNTERS)
    if pt:
        rows = []
        tot = {name: 0.0 for name in PIPELINE_COUNTERS}
        for rnd in sorted(pt):
            row = pt[rnd]
            for name in PIPELINE_COUNTERS:
                tot[name] += row.get(name, 0.0)
            rows.append([
                str(rnd),
                (_fmt_bytes(row["h2d_bytes"]) if "h2d_bytes" in row
                 else "—"),
                (_fmt_ms(row["prefetch_overlap_s"])
                 if "prefetch_overlap_s" in row else "—"),
                (_fmt_ms(row["dispatch_wait_s"])
                 if "dispatch_wait_s" in row else "—"),
                (_fmt_ms(row["warm_up_s"]) if "warm_up_s" in row else "—"),
            ])
        rows.append(["total", _fmt_bytes(tot["h2d_bytes"]),
                     _fmt_ms(tot["prefetch_overlap_s"]),
                     _fmt_ms(tot["dispatch_wait_s"]),
                     _fmt_ms(tot["warm_up_s"])])
        out.append("\n-- pipeline (H2D bytes, hidden planning ms, prefetch "
                   "stall ms, warm-up ms) --")
        out.append(_table(["round", "h2d", "overlap", "stall", "warm_up"],
                          rows))
    if len(out) <= (1 if title else 0):
        out.append("(no span or round records found)")
    return "\n".join(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("events", nargs="+",
                    help="JSONL event file(s) written by repro.obs.JsonlSink")
    args = ap.parse_args(argv)
    status = 0
    for path in args.events:
        try:
            records = load_events(path)
        except OSError as e:
            print(f"[timeline] cannot read {path}: {e}", file=sys.stderr)
            status = 1
            continue
        print(render(records, title=path))
        print()
    return status


if __name__ == "__main__":
    raise SystemExit(main())
