"""One formatting path for round telemetry.

``RoundRuntime`` builds each per-round record ONCE and routes it both to
the tracer's sinks and — when ``verbose=True`` — through these formatters
to the console, so the printed numbers and the recorded numbers can never
drift apart. ``python -m repro.obs.timeline`` reuses the same helpers to
render recorded streams.
"""
from __future__ import annotations

__all__ = ["format_eval", "format_replan"]


def format_eval(method: str, rec: dict) -> str:
    """The per-eval progress line (the former ``RoundRuntime.run`` verbose
    print, now rendered from the recorded event fields)."""
    fleet_bit = ""
    if rec.get("available") is not None:
        fleet_bit = (f"avail {rec['available']:4d} "
                     f"cohort {rec['cohort']:3d} ")
    return (f"[{method}] round {rec['round']:3d} {fleet_bit}"
            f"time {rec['sim_total']:9.2f} "
            f"deadline {rec['T_deadline']:7.3f} acc {rec['acc']:.4f}")


def format_replan(method: str, rec: dict) -> str:
    """The mid-run re-solve line, rendered from a ``ReplanEvent`` dict."""
    return (f"[{method}] replan @ round {rec['round'] + 1}: "
            f"reachable {rec['reachable']} -> U_est {rec['U_est']}, "
            f"m {rec['m']:.2f}, "
            f"T_tail[{len(rec['T_tail'])}] sum {sum(rec['T_tail']):.2f}")
