"""Lightweight, dependency-free tracing core for the round runtime.

A :class:`Tracer` records three kinds of structured events:

* **phase spans** — nestable, monotonic-clock timed sections
  (``plan`` / ``cohort`` / ``stack`` / ``local_train`` / ``aggregate`` /
  ``eval`` / ``replan`` / ``checkpoint``), emitted on span exit with
  duration, nesting depth, parent phase, and a global sequence number;
* **typed counters / gauges** — monotonically accumulated counts
  (padded-vs-real batch elements, bytes aggregated per backend, replan
  solver steps) and last-value gauges (cohort size);
* **ledger events** — one ``kind="round"`` record per executed round
  carrying the clock-model ledger fields (:mod:`repro.obs.ledger`:
  deadline ``T_t`` vs simulated round time vs measured host wall time,
  predicted vs realized straggler depths).

Every record is a plain dict fanned out to the attached sinks:
:class:`JsonlSink` appends JSON lines to a file (the
``python -m repro.obs.timeline`` input), :class:`MemorySink` keeps them in
a list (tests, in-process consumers).

The default tracer everywhere is the :data:`NULL_TRACER` singleton — every
method is a no-op, ``active`` is False so instrumented call sites skip
record construction entirely, and trajectories are bit-identical with or
without it (tracing never touches PRNG keys or numerics; an active tracer
only adds ``jax.block_until_ready`` fences so span durations measure real
device work instead of async dispatch).

All span timing flows through :func:`now` (``time.perf_counter``) — the
monotonic clock benchmark call sites share, so recorded durations are
NTP-proof.
"""
from __future__ import annotations

import io
import json
import os
import time
from typing import Any, Callable, Optional

__all__ = ["now", "PHASES", "Sink", "MemorySink", "JsonlSink", "Span",
           "Tracer", "NullTracer", "NULL_TRACER", "make_tracer",
           "tree_bytes"]

# canonical phase order of one federated round (timeline rendering order);
# warm_up is the pre-round-0 AOT trace/compile/execute of the round + eval
# steps (prefetch pipeline), charged to the round that triggered it
PHASES = ("warm_up", "cohort", "replan", "plan", "stack", "local_train",
          "aggregate", "eval", "checkpoint")


def now() -> float:
    """Monotonic timestamp in seconds (``time.perf_counter``).

    The single timing primitive for spans AND benchmark wall-clocks:
    ``time.time()`` can jump under NTP slew, so durations computed from it
    are not trustworthy on shared CI runners.
    """
    return time.perf_counter()


def tree_bytes(tree: Any) -> int:
    """Total buffer bytes across a pytree's array leaves."""
    import jax
    return sum(getattr(leaf, "nbytes", 0) for leaf in jax.tree.leaves(tree))


def _json_default(o):
    """Best-effort JSON coercion for numpy scalars / arrays in records."""
    if hasattr(o, "item"):
        return o.item()
    if hasattr(o, "tolist"):
        return o.tolist()
    return str(o)


class Sink:
    """Consumer of telemetry records (plain dicts)."""

    def emit(self, rec: dict) -> None:  # pragma: no cover - interface
        raise NotImplementedError

    def close(self) -> None:
        pass


class MemorySink(Sink):
    """Keeps every record in ``self.records`` (tests, in-process readers)."""

    def __init__(self):
        self.records: list[dict] = []

    def emit(self, rec: dict) -> None:
        self.records.append(rec)


class JsonlSink(Sink):
    """Appends one JSON object per record to ``path`` (created eagerly so a
    crashed run still leaves a parseable prefix)."""

    def __init__(self, path: str):
        self.path = path
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        self._f: Optional[io.TextIOBase] = open(path, "w")

    def emit(self, rec: dict) -> None:
        if self._f is not None:
            self._f.write(json.dumps(rec, default=_json_default) + "\n")

    def close(self) -> None:
        if self._f is not None:
            self._f.flush()
            self._f.close()
            self._f = None


class Span:
    """One nestable timed phase; emitted as a record when the span exits."""

    __slots__ = ("_tr", "name", "attrs", "t0")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self._tr = tracer
        self.name = name
        self.attrs = attrs

    def __enter__(self) -> "Span":
        self._tr._stack.append(self.name)
        self.t0 = self._tr.clock()
        return self

    def __exit__(self, *exc) -> bool:
        tr = self._tr
        t1 = tr.clock()
        tr._stack.pop()
        rec = {"kind": "span", "name": self.name,
               "round": tr._round,
               "t0": self.t0, "dur_s": t1 - self.t0,
               "depth": len(tr._stack),
               "parent": tr._stack[-1] if tr._stack else None,
               "seq": tr._next_seq()}
        if self.attrs:
            rec.update(self.attrs)
        tr._note_span(rec)
        tr._emit(rec)
        return False


class Tracer:
    """Collects spans / counters / gauges / events and fans them out to
    sinks, while aggregating an in-memory summary (per-phase totals,
    counter totals, the per-round clock-model ledger).

    ``clock`` is injectable for deterministic tests; it defaults to the
    monotonic :func:`now`.
    """

    active = True

    def __init__(self, sinks: Any = (), *, clock: Callable[[], float] = now):
        if isinstance(sinks, Sink):
            sinks = (sinks,)
        self.sinks: list[Sink] = list(sinks)
        self.clock = clock
        self._stack: list[str] = []
        self._seq = 0
        self._round: Optional[int] = None
        # aggregated summary state
        self.phase_totals: dict[str, dict] = {}
        self.counters: dict[str, float] = {}
        self.gauges: dict[str, float] = {}
        self.rounds: list[dict] = []       # kind="round" ledger records

    # ------------------------------------------------------------------
    def _next_seq(self) -> int:
        self._seq += 1
        return self._seq

    def _emit(self, rec: dict) -> None:
        for s in self.sinks:
            s.emit(rec)

    def _note_span(self, rec: dict) -> None:
        agg = self.phase_totals.setdefault(rec["name"],
                                           {"count": 0, "total_s": 0.0})
        agg["count"] += 1
        agg["total_s"] += rec["dur_s"]

    # ------------------------------------------------------------------
    def set_round(self, t: Optional[int]) -> None:
        """Stamp subsequent records with round number ``t`` (1-based)."""
        self._round = t

    def span(self, name: str, **attrs) -> Span:
        return Span(self, name, attrs)

    def span_record(self, name: str, t0: float, dur_s: float,
                    **attrs) -> None:
        """Emit a span measured elsewhere (the prefetch worker times round
        t+1's host phases off-thread and the runtime emits them at consume
        time, so sink writes and summary aggregation stay single-threaded).
        Identical record shape to a :class:`Span` exit; nesting fields
        reflect the emission point (the worker runs phases un-nested)."""
        rec = {"kind": "span", "name": name, "round": self._round,
               "t0": t0, "dur_s": dur_s,
               "depth": len(self._stack),
               "parent": self._stack[-1] if self._stack else None,
               "seq": self._next_seq()}
        if attrs:
            rec.update(attrs)
        self._note_span(rec)
        self._emit(rec)

    def count(self, name: str, value: float = 1, **attrs) -> None:
        self.counters[name] = self.counters.get(name, 0) + value
        self._emit({"kind": "count", "name": name, "round": self._round,
                    "value": value, **attrs})

    def gauge(self, name: str, value: float, **attrs) -> None:
        self.gauges[name] = value
        self._emit({"kind": "gauge", "name": name, "round": self._round,
                    "value": value, **attrs})

    def event(self, kind: str, **fields) -> None:
        rec = {"kind": kind, "round": self._round, **fields}
        if kind == "round":
            self.rounds.append(rec)
        self._emit(rec)

    # ------------------------------------------------------------------
    def summary(self) -> dict:
        """JSON-ready aggregate: per-phase wall totals, counter totals, the
        per-round clock-model ledger, and its drift statistics
        (:func:`repro.obs.ledger.drift_summary`)."""
        from repro.obs.ledger import drift_summary
        phases = {name: {"count": int(d["count"]),
                         "total_s": round(float(d["total_s"]), 6)}
                  for name, d in self.phase_totals.items()}
        ledger = [{k: v for k, v in r.items() if k != "kind"}
                  for r in self.rounds]
        return {"phases": phases,
                "counters": {k: (int(v) if float(v).is_integer() else
                                 round(float(v), 6))
                             for k, v in self.counters.items()},
                "gauges": {k: float(v) for k, v in self.gauges.items()},
                "ledger": ledger,
                "drift": drift_summary(self.rounds)}

    def close(self) -> None:
        for s in self.sinks:
            s.close()


class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """Do-nothing tracer: the zero-overhead default everywhere.

    ``active`` is False so instrumented call sites skip building records /
    blocking on device results entirely; the remaining per-call cost is one
    attribute check plus a no-op context manager.
    """

    active = False

    def set_round(self, t) -> None:
        pass

    def span(self, name: str, **attrs) -> _NullSpan:
        return _NULL_SPAN

    def span_record(self, name: str, t0: float, dur_s: float,
                    **attrs) -> None:
        pass

    def count(self, name: str, value: float = 1, **attrs) -> None:
        pass

    def gauge(self, name: str, value: float, **attrs) -> None:
        pass

    def event(self, kind: str, **fields) -> None:
        pass

    def summary(self) -> dict:
        return {}

    def close(self) -> None:
        pass


NULL_TRACER = NullTracer()


def make_tracer(events: Optional[str] = None, *,
                sinks: Any = None) -> Tracer | NullTracer:
    """Convenience constructor for CLIs / benchmarks: a :class:`Tracer`
    writing JSONL to ``events`` (and/or the given sinks), or the
    :data:`NULL_TRACER` when neither is given."""
    out: list[Sink] = []
    if events:
        out.append(JsonlSink(events))
    if sinks is not None:
        out.extend((sinks,) if isinstance(sinks, Sink) else list(sinks))
    if not out:
        return NULL_TRACER
    return Tracer(out)
