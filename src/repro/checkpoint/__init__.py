"""Pytree checkpointing: a flat .npz of leaves + a JSON manifest holding the
treedef and metadata (round index, simulated clock, schedule)."""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np

PyTree = Any

__all__ = ["save_checkpoint", "load_checkpoint", "latest_checkpoint"]


def save_checkpoint(path: str, params: PyTree, *, step: int = 0,
                    meta: dict | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    leaves, treedef = jax.tree.flatten(params)
    arrays = {f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)}
    np.savez(path + ".npz", **arrays)
    manifest = {"treedef": str(treedef), "n_leaves": len(leaves),
                "step": step, "meta": meta or {},
                "dtypes": [str(np.asarray(l).dtype) for l in leaves],
                "shapes": [list(np.asarray(l).shape) for l in leaves]}
    with open(path + ".json", "w") as f:
        json.dump(manifest, f, indent=1)


def load_checkpoint(path: str, like: PyTree) -> tuple[PyTree, dict]:
    """Restore into the structure of ``like`` (shapes are validated)."""
    with open(path + ".json") as f:
        manifest = json.load(f)
    data = np.load(path + ".npz")
    leaves, treedef = jax.tree.flatten(like)
    assert len(leaves) == manifest["n_leaves"], "structure mismatch"
    new_leaves = []
    for i, ref in enumerate(leaves):
        arr = data[f"leaf_{i}"]
        assert tuple(arr.shape) == tuple(np.asarray(ref).shape), \
            f"leaf {i}: {arr.shape} != {np.asarray(ref).shape}"
        new_leaves.append(arr.astype(np.asarray(ref).dtype))
    return jax.tree.unflatten(treedef, new_leaves), manifest


def latest_checkpoint(directory: str, prefix: str = "ckpt") -> str | None:
    if not os.path.isdir(directory):
        return None
    best, best_step = None, -1
    for fn in os.listdir(directory):
        if fn.startswith(prefix) and fn.endswith(".json"):
            try:
                with open(os.path.join(directory, fn)) as f:
                    step = json.load(f).get("step", 0)
            except Exception:
                continue
            if step > best_step:
                best, best_step = os.path.join(directory, fn[:-5]), step
    return best
