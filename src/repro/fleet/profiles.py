"""Device-profile registry: named fleet presets and JSON trace files.

A :class:`Fleet` is the static description of a device population — one
compute rate ``P_u`` (samples/sec per layer, Model Formulation B1), one
communication time ``B_u`` (seconds, B2), and one memory tier per device.
Presets sample these from parameterized distributions modelled on the
populations in the heterogeneity-aware FL literature (TimelyFL / FedEL
style device mixes):

* ``uniform``        — the seed repro's population: log-uniform P over a
                       ~4x spread, moderate network times.
* ``bimodal-edge``   — 70% slow edge boxes + 30% fast gateways; the slow
                       mode also has worse links.
* ``longtail-mobile``— lognormal P with a heavy right tail: a mass of
                       mid/slow phones and a few flagship devices; Pareto
                       network tail (congested uplinks).
* ``datacenter``     — tightly clustered fast workers with near-zero
                       network time.

``load_trace``/``save_trace`` round-trip a fleet through a JSON file with
one record per device, so measured traces can replace synthetic presets.
"""
from __future__ import annotations

import dataclasses
import json
import zlib
from typing import Callable

import numpy as np

__all__ = ["Fleet", "PRESETS", "preset", "make_fleet", "make_population",
           "fleet_from_config", "load_trace", "save_trace", "load_mobiperf"]


@dataclasses.dataclass(frozen=True)
class Fleet:
    """Static per-device capabilities of a simulated population."""

    name: str
    P: np.ndarray        # (n,) compute rate P_u, samples/sec per layer (B1)
    B: np.ndarray        # (n,) communication time B_u, seconds (B2)
    tier: np.ndarray     # (n,) memory tier 0 (small) .. 2 (large)

    def __post_init__(self):
        object.__setattr__(self, "P", np.asarray(self.P, np.float32))
        object.__setattr__(self, "B", np.asarray(self.B, np.float32))
        object.__setattr__(self, "tier", np.asarray(self.tier, np.int32))
        assert self.P.shape == self.B.shape == self.tier.shape
        assert self.P.ndim == 1 and self.size > 0
        assert float(self.P.min()) > 0.0

    @property
    def size(self) -> int:
        return int(self.P.shape[0])

    def describe(self) -> dict:
        q = lambda a: [round(float(np.quantile(a, x)), 4)
                       for x in (0.05, 0.5, 0.95)]
        return {"name": self.name, "size": self.size,
                "P_q05_50_95": q(self.P), "B_q05_50_95": q(self.B),
                "tiers": np.bincount(self.tier, minlength=3).tolist()}


PRESETS: dict[str, Callable] = {}


def preset(name: str):
    """Register ``fn(n, rng) -> (P, B, tier)`` as a named fleet preset."""
    def deco(fn):
        PRESETS[name] = fn
        return fn
    return deco


def _tiers_by_speed(P: np.ndarray) -> np.ndarray:
    """Memory tier from compute terciles (fast devices carry more RAM)."""
    t1, t2 = np.quantile(P, [1 / 3, 2 / 3])
    return (P >= t1).astype(np.int32) + (P >= t2).astype(np.int32)


@preset("uniform")
def _uniform(n: int, rng: np.random.Generator):
    P = 8.0 * np.exp(rng.uniform(0.0, np.log(4.0), n)).astype(np.float32)
    B = rng.uniform(0.02, 0.08, n).astype(np.float32)
    return P, B, _tiers_by_speed(P)


@preset("bimodal-edge")
def _bimodal_edge(n: int, rng: np.random.Generator):
    fast = rng.random(n) < 0.3
    P = np.where(fast,
                 rng.lognormal(np.log(16.0), 0.15, n),
                 rng.lognormal(np.log(3.0), 0.25, n)).astype(np.float32)
    B = np.where(fast,
                 rng.uniform(0.01, 0.03, n),
                 rng.uniform(0.05, 0.15, n)).astype(np.float32)
    tier = np.where(fast, 2, rng.integers(0, 2, n)).astype(np.int32)
    return P, B, tier


@preset("longtail-mobile")
def _longtail_mobile(n: int, rng: np.random.Generator):
    P = rng.lognormal(np.log(5.0), 0.7, n).astype(np.float32)
    P = np.clip(P, 0.5, 80.0)
    # Pareto-tailed uplink times: most links fine, a congested tail
    B = (0.02 * (1.0 + rng.pareto(3.0, n))).astype(np.float32)
    B = np.clip(B, 0.02, 0.5)
    return P, B, _tiers_by_speed(P)


@preset("datacenter")
def _datacenter(n: int, rng: np.random.Generator):
    P = np.clip(rng.normal(32.0, 2.0, n), 24.0, 40.0).astype(np.float32)
    B = rng.uniform(0.001, 0.004, n).astype(np.float32)
    return P, B, np.full(n, 2, np.int32)


def make_fleet(preset_name: str, n: int, seed: int = 0) -> Fleet:
    """Sample a fleet of ``n`` devices from a named preset, deterministically
    in ``seed`` (the same (preset, n, seed) always yields the same fleet)."""
    if preset_name not in PRESETS:
        raise KeyError(
            f"unknown fleet preset {preset_name!r}; known: {sorted(PRESETS)}")
    # crc32, not hash(): str hash is salted per process and would break
    # cross-run determinism of the sampled fleet
    rng = np.random.default_rng([zlib.crc32(preset_name.encode()), seed])
    P, B, tier = PRESETS[preset_name](n, rng)
    return Fleet(name=preset_name, P=P, B=B, tier=tier)


def fleet_from_config(fc) -> Fleet:
    """Build a fleet from a :class:`repro.configs.FleetConfig` block."""
    if fc.trace_path:
        return load_trace(fc.trace_path)
    if fc.preset not in PRESETS:
        # an explicit error here (not just make_fleet's KeyError) so config
        # typos name the config field AND the registry
        raise ValueError(
            f"FleetConfig.preset {fc.preset!r} is not a registered fleet "
            f"preset; registered presets: {sorted(PRESETS)}")
    return make_fleet(fc.preset, fc.size, seed=fc.seed)


def make_population(spec, **kwargs):
    """Unified population factory (presets, traces, MobiPerf logs, and
    lazy parametric populations behind one spec).

    A convenience re-export of
    :func:`repro.fleet.population.make_population` so the three fleet
    constructors (:func:`make_fleet`, :func:`load_trace`,
    :func:`load_mobiperf`) share one front door keyed by a spec
    string/dict — see :class:`repro.fleet.population.PopulationSpec` for
    the source forms. Imported lazily: ``population`` depends on this
    module.
    """
    from repro.fleet.population import make_population as _make_population
    return _make_population(spec, **kwargs)


def save_trace(fleet: Fleet, path: str) -> str:
    payload = {"name": fleet.name,
               "devices": [{"P": float(p), "B": float(b), "tier": int(t)}
                           for p, b, t in zip(fleet.P, fleet.B, fleet.tier)]}
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def load_trace(path: str) -> Fleet:
    with open(path) as f:
        payload = json.load(f)
    dev = payload["devices"]
    if not dev:
        raise ValueError(f"trace {path!r} has no devices")
    return Fleet(name=payload.get("name", "trace"),
                 P=np.asarray([d["P"] for d in dev], np.float32),
                 B=np.asarray([d["B"] for d in dev], np.float32),
                 tier=np.asarray([d.get("tier", 1) for d in dev], np.int32))


def load_mobiperf(path: str, *, model_mbits: float = 16.0,
                  rate_per_ghz_core: float = 2.0) -> Fleet:
    """Import a MobiPerf-style measurement log as a :class:`Fleet`.

    MobiPerf-family logs are a flat JSON list of per-measurement records;
    each record names a device and carries network measurements plus
    static device properties::

        [{"device_id": "a1", "timestamp": "...",
          "properties": {"cpu_ghz": 2.4, "cpu_cores": 8, "ram_gb": 8},
          "values": {"tcp_speed_results_kbps": 41800, "rtt_ms": 42.0}},
         ...]

    Records are grouped by ``device_id`` (one fleet device per id) and the
    medians of repeated measurements are mapped onto the paper's model
    formulations:

    * **P_u (B1)**: compute rate ``rate_per_ghz_core * cpu_ghz *
      cpu_cores`` samples/sec per layer — a linear proxy; calibrate
      ``rate_per_ghz_core`` against a measured device if available.
    * **B_u (B2)**: per-round communication time = median RTT plus the
      time to move ``model_mbits`` of update traffic at the median
      measured throughput.
    * **tier**: memory tier from RAM (<3 GB -> 0, <6 GB -> 1, else 2).

    Devices missing throughput or RTT fall back to the slowest observed
    value (a congested-link assumption, matching how MobiPerf treats
    failed probes).
    """
    with open(path) as f:
        records = json.load(f)
    if isinstance(records, dict):
        records = records.get("measurements", [])
    by_dev: dict[str, list] = {}
    for rec in records:
        dev = rec.get("device_id")
        if dev is not None:
            by_dev.setdefault(str(dev), []).append(rec)
    if not by_dev:
        raise ValueError(f"mobiperf log {path!r} has no device_id records")

    def _median(vals, fallback):
        vals = [v for v in vals if v is not None and v > 0]
        return float(np.median(vals)) if vals else fallback

    P, B, tier = [], [], []
    all_kbps = [v for recs in by_dev.values() for r in recs
                if (v := r.get("values", {}).get("tcp_speed_results_kbps"))]
    all_rtt = [v for recs in by_dev.values() for r in recs
               if (v := r.get("values", {}).get("rtt_ms"))]
    worst_kbps = min(all_kbps) if all_kbps else 1000.0
    worst_rtt = max(all_rtt) if all_rtt else 500.0
    for dev in sorted(by_dev):
        recs = by_dev[dev]
        props = {}
        for r in recs:                      # later records override earlier
            props.update(r.get("properties", {}))
        ghz = float(props.get("cpu_ghz", 1.5))
        cores = float(props.get("cpu_cores", 4))
        ram = float(props.get("ram_gb", 4))
        kbps = _median([r.get("values", {}).get("tcp_speed_results_kbps")
                        for r in recs], worst_kbps)
        rtt = _median([r.get("values", {}).get("rtt_ms")
                       for r in recs], worst_rtt)
        P.append(max(rate_per_ghz_core * ghz * cores, 1e-3))
        B.append(rtt / 1e3 + model_mbits * 1e3 / max(kbps, 1.0))
        tier.append(0 if ram < 3 else (1 if ram < 6 else 2))
    return Fleet(name="mobiperf", P=np.asarray(P, np.float32),
                 B=np.asarray(B, np.float32),
                 tier=np.asarray(tier, np.int32))
