"""Pluggable availability / churn models for simulated device fleets.

Each model answers, per round ``t``, which devices are reachable:
``step(t) -> bool (n,)``. Models are stateful where the dynamics demand it
(Markov on/off chains carry per-device state between rounds) and fully
deterministic given their seed and the sequence of ``step`` calls;
``reset()`` rewinds to the initial state.

* ``always-on``  — every device reachable every round (the seed repro).
* ``bernoulli``  — iid per-device, per-round reachability with rate ``rate``.
* ``diurnal``    — sine-wave day/night cycle: availability probability
                   ``mean + amplitude * sin(2 pi t / period + phase_u)``
                   with a per-device phase (devices live in time zones).
                   ``phase_spread`` narrows the time-zone spread: at the
                   default ``2 pi`` phases wash out fleet-wide, while small
                   spreads synchronize the population (one dominant time
                   zone) so the REACHABLE COUNT itself oscillates — the
                   churn regime online deadline re-planning targets.
* ``markov``     — per-device on/off Markov chain with transition probs
                   ``p_off_to_on`` / ``p_on_to_off``; stationary availability
                   is ``p_off_to_on / (p_off_to_on + p_on_to_off)``, and
                   outages are temporally correlated (sticky churn).

Every model also exposes the expected-reachable distribution consumed by
the re-planning subsystem (:mod:`repro.core.replan`): ``reachable_probs(t)``
gives each device's marginal reachability probability in a future round
``t`` conditioned on the model's current state, and ``expected_reachable(t0,
horizon)`` the expected reachable counts for the next ``horizon`` rounds.

For populations too large to instantiate a per-device model
(:class:`repro.fleet.population.ParametricPopulation`), every class also
answers the STATELESS fleet-wide marginal rate ``marginal_rate(t,
**kwargs)`` — the probability a generic device is reachable in round ``t``
with per-device state (Markov stickiness, diurnal phases) averaged out:

* ``always-on`` — 1.
* ``bernoulli`` — ``rate``.
* ``diurnal``   — the per-device probability averaged over the phase
  distribution U(0, phase_spread), clipped to [0, 1] after averaging (the
  per-device clip is approximated; exact when ``mean +- amplitude`` stays
  inside [0, 1]).
* ``markov``    — the stationary rate (temporal correlation averaged out).
"""
from __future__ import annotations

import numpy as np

__all__ = ["AvailabilityModel", "AlwaysOn", "Bernoulli", "Diurnal", "Markov",
           "AVAILABILITY", "make_availability"]


class AvailabilityModel:
    """Base class: deterministic in (seed, step-call sequence)."""

    name = "base"

    def __init__(self, n: int, seed: int = 0):
        self.n = int(n)
        self.seed = int(seed)
        self.reset()

    def reset(self) -> None:
        self._rng = np.random.default_rng([1021, self.seed])
        self._init_state()

    def _init_state(self) -> None:
        pass

    def step(self, t: int) -> np.ndarray:  # pragma: no cover
        """Reachability of every device in round ``t`` -> bool (n,)."""
        raise NotImplementedError

    def reachable_probs(self, t: int) -> np.ndarray:  # pragma: no cover
        """Per-device probability of being reachable in round ``t`` given
        the model's current state -> float (n,)."""
        raise NotImplementedError

    def expected_reachable(self, t0: int, horizon: int = 1) -> np.ndarray:
        """Expected reachable-device count for rounds ``t0..t0+horizon-1``.

        The population estimator behind availability-aware deadline
        re-planning: ``sum_u P(device u reachable in round t)`` per round.
        """
        return np.asarray([float(self.reachable_probs(t0 + k).sum())
                           for k in range(horizon)])

    def describe(self) -> dict:
        return {"name": self.name, "n": self.n}

    @classmethod
    def marginal_rate(cls, t: int, **kwargs) -> float:  # pragma: no cover
        """Stateless fleet-wide reachability rate at round ``t`` (see the
        module docstring) — the analytic hook parametric populations use
        instead of instantiating an ``n``-device model."""
        raise NotImplementedError


class AlwaysOn(AvailabilityModel):
    name = "always-on"

    def step(self, t: int) -> np.ndarray:
        return np.ones(self.n, bool)

    def reachable_probs(self, t: int) -> np.ndarray:
        return np.ones(self.n)

    @classmethod
    def marginal_rate(cls, t: int, **kwargs) -> float:
        return 1.0


class Bernoulli(AvailabilityModel):
    name = "bernoulli"

    def __init__(self, n: int, seed: int = 0, rate: float = 0.8):
        self.rate = float(rate)
        super().__init__(n, seed)

    def step(self, t: int) -> np.ndarray:
        return self._rng.random(self.n) < self.rate

    def reachable_probs(self, t: int) -> np.ndarray:
        return np.full(self.n, self.rate)

    @classmethod
    def marginal_rate(cls, t: int, rate: float = 0.8, **kwargs) -> float:
        return float(rate)

    def describe(self) -> dict:
        return {"name": self.name, "n": self.n, "rate": self.rate}


class Diurnal(AvailabilityModel):
    name = "diurnal"

    def __init__(self, n: int, seed: int = 0, mean: float = 0.65,
                 amplitude: float = 0.3, period: float = 24.0,
                 phase_spread: float = 2.0 * np.pi):
        self.mean = float(mean)
        self.amplitude = float(amplitude)
        self.period = float(period)
        self.phase_spread = float(phase_spread)
        super().__init__(n, seed)

    def _init_state(self) -> None:
        self.phase = self._rng.uniform(0.0, self.phase_spread, self.n)

    def prob(self, t: int) -> np.ndarray:
        raw = self.mean + self.amplitude * np.sin(
            2.0 * np.pi * t / self.period + self.phase)
        return np.clip(raw, 0.0, 1.0)

    def step(self, t: int) -> np.ndarray:
        return self._rng.random(self.n) < self.prob(t)

    def reachable_probs(self, t: int) -> np.ndarray:
        return self.prob(t)

    @classmethod
    def marginal_rate(cls, t: int, mean: float = 0.65,
                      amplitude: float = 0.3, period: float = 24.0,
                      phase_spread: float = 2.0 * np.pi,
                      **kwargs) -> float:
        """Phase-averaged rate: E_phi[mean + amplitude sin(a + phi)] with
        phi ~ U(0, phase_spread) integrates to amplitude (cos a -
        cos(a + spread)) / spread; the [0, 1] clip is applied AFTER the
        phase average (see the module docstring for the approximation)."""
        a = 2.0 * np.pi * float(t) / float(period)
        spread = max(float(phase_spread), 1e-9)
        mean_sin = (np.cos(a) - np.cos(a + spread)) / spread
        return float(np.clip(float(mean) + float(amplitude) * mean_sin,
                             0.0, 1.0))

    def describe(self) -> dict:
        return {"name": self.name, "n": self.n, "mean": self.mean,
                "amplitude": self.amplitude, "period": self.period,
                "phase_spread": round(self.phase_spread, 4)}


class Markov(AvailabilityModel):
    name = "markov"

    def __init__(self, n: int, seed: int = 0, p_off_to_on: float = 0.3,
                 p_on_to_off: float = 0.1):
        self.p_up = float(p_off_to_on)
        self.p_down = float(p_on_to_off)
        super().__init__(n, seed)

    @property
    def stationary(self) -> float:
        return self.p_up / max(self.p_up + self.p_down, 1e-12)

    def _init_state(self) -> None:
        # start from the stationary distribution so rates hold from round 0
        self.state = self._rng.random(self.n) < self.stationary
        self._t = -1          # round of the last step() (state's timestamp)

    def step(self, t: int) -> np.ndarray:
        u = self._rng.random(self.n)
        self.state = np.where(self.state, u >= self.p_down, u < self.p_up)
        self._t = int(t)
        return self.state.copy()

    def reachable_probs(self, t: int) -> np.ndarray:
        """k-step-ahead marginal: geometric relaxation of the current state
        toward the stationary rate with factor (1 - p_up - p_down)^k."""
        k = max(int(t) - self._t, 0)
        lam = (1.0 - self.p_up - self.p_down) ** k
        return self.stationary + (self.state.astype(float)
                                  - self.stationary) * lam

    @classmethod
    def marginal_rate(cls, t: int, p_off_to_on: float = 0.3,
                      p_on_to_off: float = 0.1, **kwargs) -> float:
        """Stationary rate — the chain's temporal stickiness is averaged
        out (states started from the stationary distribution stay there
        marginally)."""
        return float(p_off_to_on) / max(float(p_off_to_on)
                                        + float(p_on_to_off), 1e-12)

    def describe(self) -> dict:
        return {"name": self.name, "n": self.n, "p_off_to_on": self.p_up,
                "p_on_to_off": self.p_down}


AVAILABILITY = {m.name: m for m in (AlwaysOn, Bernoulli, Diurnal, Markov)}


def make_availability(name: str, n: int, seed: int = 0,
                      **kwargs) -> AvailabilityModel:
    if name not in AVAILABILITY:
        raise KeyError(
            f"unknown availability model {name!r}; known: {sorted(AVAILABILITY)}")
    return AVAILABILITY[name](n, seed=seed, **kwargs)
