"""Pluggable availability / churn models for simulated device fleets.

Each model answers, per round ``t``, which devices are reachable:
``step(t) -> bool (n,)``. Models are stateful where the dynamics demand it
(Markov on/off chains carry per-device state between rounds) and fully
deterministic given their seed and the sequence of ``step`` calls;
``reset()`` rewinds to the initial state.

* ``always-on``  — every device reachable every round (the seed repro).
* ``bernoulli``  — iid per-device, per-round reachability with rate ``rate``.
* ``diurnal``    — sine-wave day/night cycle: availability probability
                   ``mean + amplitude * sin(2 pi t / period + phase_u)``
                   with a per-device phase (devices live in time zones).
* ``markov``     — per-device on/off Markov chain with transition probs
                   ``p_off_to_on`` / ``p_on_to_off``; stationary availability
                   is ``p_off_to_on / (p_off_to_on + p_on_to_off)``, and
                   outages are temporally correlated (sticky churn).
"""
from __future__ import annotations

import numpy as np

__all__ = ["AvailabilityModel", "AlwaysOn", "Bernoulli", "Diurnal", "Markov",
           "AVAILABILITY", "make_availability"]


class AvailabilityModel:
    """Base class: deterministic in (seed, step-call sequence)."""

    name = "base"

    def __init__(self, n: int, seed: int = 0):
        self.n = int(n)
        self.seed = int(seed)
        self.reset()

    def reset(self) -> None:
        self._rng = np.random.default_rng([1021, self.seed])
        self._init_state()

    def _init_state(self) -> None:
        pass

    def step(self, t: int) -> np.ndarray:  # pragma: no cover
        """Reachability of every device in round ``t`` -> bool (n,)."""
        raise NotImplementedError

    def describe(self) -> dict:
        return {"name": self.name, "n": self.n}


class AlwaysOn(AvailabilityModel):
    name = "always-on"

    def step(self, t: int) -> np.ndarray:
        return np.ones(self.n, bool)


class Bernoulli(AvailabilityModel):
    name = "bernoulli"

    def __init__(self, n: int, seed: int = 0, rate: float = 0.8):
        self.rate = float(rate)
        super().__init__(n, seed)

    def step(self, t: int) -> np.ndarray:
        return self._rng.random(self.n) < self.rate

    def describe(self) -> dict:
        return {"name": self.name, "n": self.n, "rate": self.rate}


class Diurnal(AvailabilityModel):
    name = "diurnal"

    def __init__(self, n: int, seed: int = 0, mean: float = 0.65,
                 amplitude: float = 0.3, period: float = 24.0):
        self.mean = float(mean)
        self.amplitude = float(amplitude)
        self.period = float(period)
        super().__init__(n, seed)

    def _init_state(self) -> None:
        self.phase = self._rng.uniform(0.0, 2.0 * np.pi, self.n)

    def prob(self, t: int) -> np.ndarray:
        raw = self.mean + self.amplitude * np.sin(
            2.0 * np.pi * t / self.period + self.phase)
        return np.clip(raw, 0.0, 1.0)

    def step(self, t: int) -> np.ndarray:
        return self._rng.random(self.n) < self.prob(t)

    def describe(self) -> dict:
        return {"name": self.name, "n": self.n, "mean": self.mean,
                "amplitude": self.amplitude, "period": self.period}


class Markov(AvailabilityModel):
    name = "markov"

    def __init__(self, n: int, seed: int = 0, p_off_to_on: float = 0.3,
                 p_on_to_off: float = 0.1):
        self.p_up = float(p_off_to_on)
        self.p_down = float(p_on_to_off)
        super().__init__(n, seed)

    @property
    def stationary(self) -> float:
        return self.p_up / max(self.p_up + self.p_down, 1e-12)

    def _init_state(self) -> None:
        # start from the stationary distribution so rates hold from round 0
        self.state = self._rng.random(self.n) < self.stationary

    def step(self, t: int) -> np.ndarray:
        u = self._rng.random(self.n)
        self.state = np.where(self.state, u >= self.p_down, u < self.p_up)
        return self.state.copy()

    def describe(self) -> dict:
        return {"name": self.name, "n": self.n, "p_off_to_on": self.p_up,
                "p_on_to_off": self.p_down}


AVAILABILITY = {m.name: m for m in (AlwaysOn, Bernoulli, Diurnal, Markov)}


def make_availability(name: str, n: int, seed: int = 0,
                      **kwargs) -> AvailabilityModel:
    if name not in AVAILABILITY:
        raise KeyError(
            f"unknown availability model {name!r}; known: {sorted(AVAILABILITY)}")
    return AVAILABILITY[name](n, seed=seed, **kwargs)
