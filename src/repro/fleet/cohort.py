"""Per-round cohort sampling from the available population.

Given the availability draw for round ``t`` and the fleet profile, a cohort
sampler picks the (at most) ``U`` distinct devices the round actually
plans for, and ``cohort_view`` re-derives the :class:`AnalysisConfig` the
policies consume — so ``AdelPolicy``/baselines see the *sampled cohort's*
``P``/``B`` each round instead of one static population.

Strategies:

* ``uniform``          — uniform without replacement over available devices.
* ``power-of-choice``  — draw ``oversample * U`` candidates, keep the ``U``
                         fastest by ``P_u`` (compute-capability variant of
                         power-of-choice client selection).
* ``stratified``       — proportional allocation across memory tiers
                         (largest-remainder rounding), uniform within tier;
                         guarantees tier coverage for width/memory studies.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.types import AnalysisConfig
from repro.fleet.profiles import Fleet

__all__ = ["COHORT_STRATEGIES", "sample_cohort", "cohort_view",
           "profile_view"]

COHORT_STRATEGIES = ("uniform", "power-of-choice", "stratified")


def _stratified(rng: np.random.Generator, avail_idx: np.ndarray,
                tier: np.ndarray, U: int) -> np.ndarray:
    tiers, counts = np.unique(tier[avail_idx], return_counts=True)
    quota = U * counts / counts.sum()
    take = np.floor(quota).astype(int)
    # largest-remainder rounding up to exactly U
    for i in np.argsort(-(quota - take)):
        if take.sum() >= U:
            break
        take[i] += 1
    take = np.minimum(take, counts)
    picked = []
    for tr, k in zip(tiers, take):
        pool = avail_idx[tier[avail_idx] == tr]
        picked.append(rng.choice(pool, size=int(k), replace=False))
    out = np.concatenate(picked) if picked else np.empty(0, np.int64)
    # tiers exhausted below quota: top up uniformly from the rest
    if len(out) < U:
        rest = np.setdiff1d(avail_idx, out, assume_unique=False)
        out = np.concatenate(
            [out, rng.choice(rest, size=U - len(out), replace=False)])
    return out


def sample_cohort(rng: np.random.Generator, available: np.ndarray,
                  fleet: Fleet, U: int, strategy: str = "uniform",
                  oversample: int = 2) -> np.ndarray:
    """Pick at most ``U`` distinct available device indices.

    Returns every available device when fewer than ``U`` are reachable
    (the round proceeds with a reduced cohort), and an empty array when
    nobody is.
    """
    avail_idx = np.flatnonzero(np.asarray(available))
    if len(avail_idx) <= U:
        return avail_idx
    if strategy == "uniform":
        return np.sort(rng.choice(avail_idx, size=U, replace=False))
    if strategy == "power-of-choice":
        k = min(len(avail_idx), oversample * U)
        cand = rng.choice(avail_idx, size=k, replace=False)
        return np.sort(cand[np.argsort(-fleet.P[cand])[:U]])
    if strategy == "stratified":
        return np.sort(_stratified(rng, avail_idx, fleet.tier, U))
    raise ValueError(
        f"unknown cohort strategy {strategy!r}; known: {COHORT_STRATEGIES}")


def profile_view(base: AnalysisConfig, P: np.ndarray,
                 B: np.ndarray) -> AnalysisConfig:
    """The round's AnalysisConfig from the cohort's sampled profiles.

    The population-protocol form of :func:`cohort_view`: any
    :class:`repro.fleet.population.Population` hands over the cohort's
    ``(P, B)`` arrays directly (materialized gathers, parametric lazy
    draws) and the view never touches fleet-sized state.
    """
    U = len(P)
    sigma2 = np.full((U,), float(np.mean(base.sigma2)), np.float32)
    return dataclasses.replace(base, U=U, P=np.asarray(P, np.float32),
                               B=np.asarray(B, np.float32), sigma2=sigma2)


def cohort_view(base: AnalysisConfig, fleet: Fleet,
                idx: np.ndarray) -> AnalysisConfig:
    """The round's AnalysisConfig: base constants with the cohort's U/P/B."""
    return profile_view(base, fleet.P[idx], fleet.B[idx])
