"""Fleet simulation: trace-driven heterogeneous device fleets at
thousands-of-clients scale.

The seed repro exercised one small, statically stacked client set whose
``P_u``/``B_u`` were fixed at config time. This subsystem is the layer
between data/config and the FL runtime that lets every policy be evaluated
against realistic populations:

* :mod:`repro.fleet.profiles` — device-profile registry. Named presets
  (``uniform``, ``bimodal-edge``, ``longtail-mobile``, ``datacenter``)
  sample per-device compute rates ``P_u``, network times ``B_u`` and memory
  tiers from parameterized distributions; ``load_trace``/``save_trace``
  round-trip fleets through JSON device traces.
* :mod:`repro.fleet.availability` — pluggable churn models deciding who is
  reachable each round: ``always-on``, ``bernoulli``, ``diurnal``
  (sine-wave day/night with per-device phase), ``markov`` (sticky on/off).
* :mod:`repro.fleet.cohort` — per-round cohort sampling (``uniform``,
  ``power-of-choice`` by ``P_u``, ``stratified`` by tier) and
  ``cohort_view``/``profile_view``, which re-derive the
  :class:`AnalysisConfig` the policies consume so ADEL/baselines see the
  sampled cohort's ``P``/``B``.
* :mod:`repro.fleet.population` — the :class:`Population` protocol behind
  million-device fleets: :class:`MaterializedPopulation` wraps a
  :class:`Fleet` + availability model bit-for-bit, while
  :class:`ParametricPopulation` draws device profiles lazily from
  per-tier two-piece lognormal fits of a preset's quantile statistics,
  so cost is O(cohort) regardless of fleet size.
  :class:`PopulationSpec` / :func:`make_population` are the one front
  door (``"PRESET"`` | ``"trace:PATH"`` | ``"mobiperf:PATH"`` |
  ``"parametric:PRESET"``) with a shared ``--population`` CLI block.
* :mod:`repro.fleet.engine` — ``run_fleet``, a thin fleet front-end over
  the unified :class:`repro.fl.runtime.RoundRuntime`: per-round
  availability/cohort/view sampling feeds any :mod:`repro.fl.backends`
  execution backend (``chunked`` by default — software psum via
  ``aggregate_grads_chunk`` — or ``dense`` / ``shard_map``), so large
  fleets never materialize ``(fleet, N, ...)`` arrays.
* :mod:`repro.fleet.scenarios` — named scenario registry
  (fleet x availability x partition x policy) with a CLI::

      PYTHONPATH=src python -m repro.fleet.scenarios --list
      PYTHONPATH=src python -m repro.fleet.scenarios \
          --run longtail-mobile-diurnal --rounds 5

  emitting History dicts consumable by ``benchmarks/report.py``.

The population block lives in :class:`repro.configs.FleetConfig`, whose
``replan`` field (a :class:`repro.core.replan.ReplanConfig`) turns on
availability-aware online re-planning: the availability models expose
``reachable_probs``/``expected_reachable`` forecasts, and
:meth:`repro.fleet.engine.FleetCohortSource.replan_view` re-estimates the
remaining-horizon Problem-2 view from the currently-reachable population
(see the ``*-replan`` scenarios and ``benchmarks/replan_sweep.py``).
"""
from repro.fleet.availability import (AVAILABILITY, AvailabilityModel,
                                      make_availability)
from repro.fleet.cohort import (COHORT_STRATEGIES, cohort_view, profile_view,
                                sample_cohort)
from repro.fleet.engine import (FleetData, partition_fleet, reference_config,
                                run_fleet)
from repro.fleet.population import (CohortDraw, MaterializedPopulation,
                                    ParametricPopulation, Population,
                                    PopulationSpec, make_population)
from repro.fleet.profiles import (PRESETS, Fleet, fleet_from_config,
                                  load_trace, make_fleet, save_trace)

__all__ = [
    "AVAILABILITY", "AvailabilityModel", "COHORT_STRATEGIES", "CohortDraw",
    "Fleet", "FleetData", "MaterializedPopulation", "PRESETS",
    "ParametricPopulation", "Population", "PopulationSpec", "cohort_view",
    "fleet_from_config", "load_trace", "make_availability", "make_fleet",
    "make_population", "partition_fleet", "profile_view", "reference_config",
    "run_fleet", "sample_cohort", "save_trace",
]
