"""``run_fleet``: the fleet-scale federated driver.

Wraps the per-round machinery of :mod:`repro.fl.server` but decouples the
*population* (thousands of devices) from the *cohort* (the ``U`` clients a
round plans for):

1. the availability model decides who is reachable,
2. a cohort sampler picks at most ``cohort_size`` devices,
3. ``cohort_view`` re-derives the AnalysisConfig the policy sees,
4. the round executes CHUNKED over a client-shard axis: client deltas are
   computed ``chunk_size`` clients at a time (one vmap per chunk) and folded
   into a running partial aggregate via
   :func:`repro.core.aggregation.aggregate_grads_chunk` with *global*
   contributor counts — a software psum, shaped exactly like the
   ``aggregate_grads_local``/``shard_map`` path, so a 2,000-device fleet
   with a 64-client cohort never materializes a ``(fleet, N, ...)`` or a
   full ``(cohort, ...)`` delta pytree.

All round-execution arrays are padded to fixed shapes (``n_pad`` samples
per client, ``cohort_size`` rounded up to a ``chunk_size`` multiple), so
jit compiles the chunk step once regardless of availability fluctuations.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import aggregate_grads_chunk
from repro.core.baselines import Policy, RoundPlan, make_policy
from repro.core.scheduler import solve
from repro.core.types import AnalysisConfig
from repro.fl.client import batched_client_deltas, sample_client_batches
from repro.fl.partition import dirichlet_partition, iid_partition, stack_clients
from repro.fl.server import History, ModelAPI, eval_metrics, make_round_step
from repro.fleet.availability import AvailabilityModel
from repro.fleet.cohort import cohort_view, sample_cohort
from repro.fleet.profiles import Fleet

__all__ = ["FleetData", "partition_fleet", "reference_config", "run_fleet"]


@dataclasses.dataclass
class FleetData:
    """Dataset + per-device shard indices (never stacked fleet-wide).

    ``parts[u]`` indexes device u's samples inside the shared ``x``/``y``
    arrays; only the per-round cohort is ever materialized as a stacked
    ``(U, n_pad, ...)`` batch.
    """

    x: np.ndarray                 # (n, ...) training inputs
    y: np.ndarray                 # (n,) training labels
    parts: list                   # len == fleet.size, index arrays into x/y
    x_test: np.ndarray
    y_test: np.ndarray

    @property
    def n_pad(self) -> int:
        return max(len(p) for p in self.parts)


def partition_fleet(x: np.ndarray, y: np.ndarray, x_test: np.ndarray,
                    y_test: np.ndarray, n_devices: int, *,
                    alpha: Optional[float] = 0.5, seed: int = 0) -> FleetData:
    """Split one dataset over ``n_devices`` shards (Dirichlet or IID)."""
    if alpha is None:
        parts = iid_partition(len(y), n_devices, seed=seed)
    else:
        parts = dirichlet_partition(y, n_devices, alpha=alpha, seed=seed)
    return FleetData(x=x, y=y, parts=parts, x_test=x_test, y_test=y_test)


def reference_config(fleet: Fleet, *, U: int, L: int, R: int, T_max: float,
                     eta0: float = 2.0, eta_decay: float = 1.0,
                     seed: int = 0) -> AnalysisConfig:
    """Planning config for the Problem-2 solver: a quantile-spaced
    representative cohort of the fleet (so the schedule reflects the real
    P/B spread rather than one random draw)."""
    q = (np.arange(U) + 0.5) / U
    order = np.argsort(fleet.P)
    pick = order[np.clip((q * fleet.size).astype(int), 0, fleet.size - 1)]
    base = AnalysisConfig.default(U=U, L=L, R=R, T_max=T_max, eta0=eta0,
                                  eta_decay=eta_decay, seed=seed)
    return dataclasses.replace(base, P=fleet.P[pick].copy(),
                               B=fleet.B[pick].copy())


def _make_chunk_step(model: ModelAPI, *, local_iters: int, l2: float,
                     bias_correct: bool) -> Callable:
    """Jitted per-chunk partial aggregate: deltas -> weighted layer sums."""

    # same argument order as fl.server.make_round_step (mask, p, eta last
    # block) — both land in the engine's step cache
    @jax.jit
    def chunk_partial(params, xb, yb, wb, mask_c, p, eta, counts):
        deltas = batched_client_deltas(model.loss, params, xb, yb, wb, eta,
                                       local_iters=local_iters, l2=l2)
        ids = model.layer_ids(params)
        return aggregate_grads_chunk(deltas, ids, mask_c, p, counts,
                                     bias_correct=bias_correct)

    return chunk_partial


def run_fleet(model: ModelAPI, fleet: Fleet, availability: AvailabilityModel,
              data: FleetData, *, method: str = "adel", rounds: int = 20,
              cohort_size: int = 32, cohort_strategy: str = "uniform",
              chunk_size: int = 16, T_max: Optional[float] = None,
              eta0: float = 2.0, eta_decay: float = 1.0,
              solver: str = "adam", solver_steps: int = 600,
              local_iters: int = 1, l2: float = 0.0,
              s_max: Optional[int] = None, eval_every: int = 1,
              seed: int = 0, verbose: bool = False) -> tuple:
    """Run up to ``rounds`` federated rounds against a simulated fleet.

    Returns ``(params, History)``; the History carries the same fields as
    :func:`repro.fl.server.run_federated` plus per-round reachable-device
    counts, so ``benchmarks/report.py`` consumes it unchanged.
    """
    if fleet.size != len(data.parts):
        raise ValueError(f"fleet size {fleet.size} != data shards "
                         f"{len(data.parts)}")
    if availability.n != fleet.size:
        raise ValueError(f"availability model over {availability.n} devices "
                         f"!= fleet size {fleet.size}")
    if T_max is None:
        # same calibration as the seed benchmarks: avg depth ~50% of layers
        T_max = rounds * model.L * 0.5

    ref = reference_config(fleet, U=cohort_size, L=model.L, R=rounds,
                           T_max=T_max, eta0=eta0, eta_decay=eta_decay,
                           seed=seed)
    schedule = None
    if method == "adel":
        schedule = solve(ref, solver,
                         **({"steps": solver_steps} if solver == "adam" else {}))
    policy: Policy = make_policy(method, ref, schedule=schedule)
    if getattr(policy, "name", "") == "heterofl":
        raise NotImplementedError(
            "run_fleet does not support HeteroFL width masks yet; use "
            "fl.server.run_federated for the static-population variant")

    if s_max is None:
        # probe against a synthetic best-case device (fleet-max P, fleet-min
        # B): per-device batch sizes (ADEL's B3) grow with P_u and shrink
        # with B_u, and the baselines' fixed batch uses the cohort MEANS —
        # both are maximized by this one-device view, so no realized cohort
        # (power-of-choice top picks, or a lucky tiny cohort under churn)
        # can plan a batch that sample_client_batches would silently clip
        view_best = dataclasses.replace(
            ref, U=1, P=np.asarray([fleet.P.max()], np.float32),
            B=np.asarray([fleet.B.min()], np.float32),
            sigma2=np.asarray([float(np.mean(ref.sigma2))], np.float32))
        probe = [policy.round(jax.random.PRNGKey(0), t, view=view_best)
                 for t in (0, rounds - 1)]
        s_max = int(max(float(jnp.max(pl.batch_sizes)) for pl in probe))
        # memory bound: batches are drawn with replacement, so allow up to
        # 4x the largest shard before clipping a (rare) extreme plan — every
        # client pays O(s_max) delta compute, and an unbounded best-case
        # bound would let one outlier device size the whole round's batch
        s_max = min(s_max, 4 * data.n_pad)
    s_max = max(s_max, 2)

    n_pad = data.n_pad
    L = model.L
    chunk_size = min(chunk_size, cohort_size)   # never vmap dead padding
    U_pad = -(-cohort_size // chunk_size) * chunk_size
    eta = ref.eta

    step_cache: dict[bool, Callable] = {}
    apply_update = jax.jit(
        lambda params, agg: jax.tree.map(lambda w, d: w - d, params, agg))

    rng = np.random.default_rng([2077, seed])
    key = jax.random.PRNGKey(seed)
    key, k_init = jax.random.split(key)
    params = model.init(k_init)
    availability.reset()

    test_x = jnp.asarray(data.x_test)
    test_y = jnp.asarray(data.y_test)

    hist = History(method=f"fleet-{policy.name}")
    elapsed = 0.0
    for t in range(rounds):
        avail = availability.step(t)
        idx = sample_cohort(rng, avail, fleet, cohort_size, cohort_strategy)
        if len(idx) == 0:
            continue  # nobody reachable: the round never starts
        view = cohort_view(ref, fleet, idx)
        key, k_round, k_batch = jax.random.split(key, 3)
        plan: RoundPlan = policy.round(k_round, t, view=view)
        if elapsed + plan.elapsed > T_max * (1 + 1e-6):
            break

        U_act = len(idx)
        xs, ys, counts = stack_clients(data.x, data.y,
                                       [data.parts[u] for u in idx],
                                       n_pad=n_pad)
        # pad the cohort axis to the fixed chunked width; padded rows carry
        # an all-zero mask, so their coefficients — and contributions — are 0
        mask = np.zeros((U_pad, L), np.float32)
        mask[:U_act] = np.asarray(plan.mask, np.float32)
        S = np.ones((U_pad,), np.int32)
        S[:U_act] = np.asarray(plan.batch_sizes, np.int32)
        if U_act < U_pad:
            pad = U_pad - U_act
            xs = np.concatenate(
                [xs, np.zeros((pad,) + xs.shape[1:], xs.dtype)])
            ys = np.concatenate([ys, np.zeros((pad,) + ys.shape[1:], ys.dtype)])
            counts = np.concatenate([counts, np.ones((pad,), np.int32)])
        counts_layer = jnp.asarray(mask.sum(0))          # (L,) global counts

        xb, yb, wb = sample_client_batches(
            k_batch, jnp.asarray(xs), jnp.asarray(ys), jnp.asarray(counts),
            jnp.asarray(S), s_max)

        bc = bool(plan.bias_correct)
        single_chunk = U_pad <= chunk_size
        if bc not in step_cache:
            step_cache[bc] = (
                make_round_step(model, local_iters=local_iters, l2=l2,
                                bias_correct=bc)
                if single_chunk else
                _make_chunk_step(model, local_iters=local_iters, l2=l2,
                                 bias_correct=bc))
        step = step_cache[bc]

        mask_j = jnp.asarray(mask)
        if single_chunk:
            # whole cohort in one chunk: reuse the server's round step
            params = step(params, xb, yb, wb, mask_j, plan.p,
                          jnp.float32(eta[t]), None)
        else:
            agg = None
            for c0 in range(0, U_pad, chunk_size):
                sl = slice(c0, c0 + chunk_size)
                part = step(params, xb[sl], yb[sl], wb[sl], mask_j[sl],
                            plan.p, jnp.float32(eta[t]), counts_layer)
                agg = part if agg is None else jax.tree.map(jnp.add, agg, part)
            params = apply_update(params, agg)

        elapsed += plan.elapsed
        if (t % eval_every == 0) or (t == rounds - 1):
            acc, loss = eval_metrics(model, params, test_x, test_y)
            hist.times.append(elapsed)
            hist.rounds.append(t + 1)
            hist.accuracy.append(acc)
            hist.deadlines.append(float(plan.elapsed))
            hist.train_loss.append(loss)
            hist.available.append(int(avail.sum()))
            if verbose:
                print(f"[fleet-{policy.name}] round {t+1:3d} "
                      f"avail {int(avail.sum()):4d}/{fleet.size} "
                      f"cohort {U_act:3d} time {elapsed:9.2f} "
                      f"deadline {plan.elapsed:7.3f} acc {acc:.4f}")
    return params, hist
