"""``run_fleet``: the fleet-scale front-end over the unified round runtime.

``run_fleet`` decouples the *population* (thousands of devices) from the
*cohort* (the ``U`` clients a round plans for) and is now a thin wrapper:

1. it builds the Problem-2 planning config (:func:`reference_config`) and
   the policy, probes ``s_max`` against a synthetic best-case device, and
2. wraps availability + cohort sampling + per-round view derivation in a
   :class:`FleetCohortSource`, then hands the loop to
   :class:`repro.fl.runtime.RoundRuntime`.

Per round the source decides who is reachable (availability model), picks
at most ``cohort_size`` devices (cohort sampler), re-derives the
AnalysisConfig the policy sees (``cohort_view``), and stacks only the
sampled cohort's shards at a fixed ``n_pad`` — never a ``(fleet, N, ...)``
array. The runtime pads the cohort axis to the execution backend's fixed
width and runs the round on any :mod:`repro.fl.backends` backend:
``chunked`` (default here — sequential software psum via
``aggregate_grads_chunk``), ``dense``, or ``shard_map`` (the chunk axis as
a real client mesh axis). HeteroFL width masks flow through all three, so
the same fleet scenario can compare layer-depth and width-scaling policies.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.baselines import Policy, make_policy
from repro.core.scheduler import solve
from repro.core.types import AnalysisConfig
from repro.fl.partition import dirichlet_partition, iid_partition, stack_clients
from repro.fl.runtime import Cohort, ModelAPI, RoundRuntime, probe_s_max
from repro.fl.spec import ExecSpec
from repro.fleet.availability import AvailabilityModel
from repro.fleet.cohort import cohort_view, sample_cohort
from repro.fleet.profiles import Fleet

__all__ = ["FleetData", "FleetCohortSource", "partition_fleet",
           "reference_config", "run_fleet"]


@dataclasses.dataclass
class FleetData:
    """Dataset + per-device shard indices (never stacked fleet-wide).

    ``parts[u]`` indexes device u's samples inside the shared ``x``/``y``
    arrays; only the per-round cohort is ever materialized as a stacked
    ``(U, n_pad, ...)`` batch.
    """

    x: np.ndarray                 # (n, ...) training inputs
    y: np.ndarray                 # (n,) training labels
    parts: list                   # len == fleet.size, index arrays into x/y
    x_test: np.ndarray
    y_test: np.ndarray

    @property
    def n_pad(self) -> int:
        return max(len(p) for p in self.parts)


def partition_fleet(x: np.ndarray, y: np.ndarray, x_test: np.ndarray,
                    y_test: np.ndarray, n_devices: int, *,
                    alpha: Optional[float] = 0.5, seed: int = 0) -> FleetData:
    """Split one dataset over ``n_devices`` shards (Dirichlet or IID)."""
    if alpha is None:
        parts = iid_partition(len(y), n_devices, seed=seed)
    else:
        parts = dirichlet_partition(y, n_devices, alpha=alpha, seed=seed)
    return FleetData(x=x, y=y, parts=parts, x_test=x_test, y_test=y_test)


def reference_config(fleet: Fleet, *, U: int, L: int, R: int, T_max: float,
                     eta0: float = 2.0, eta_decay: float = 1.0,
                     seed: int = 0) -> AnalysisConfig:
    """Planning config for the Problem-2 solver: a quantile-spaced
    representative cohort of the fleet (so the schedule reflects the real
    P/B spread rather than one random draw)."""
    q = (np.arange(U) + 0.5) / U
    order = np.argsort(fleet.P)
    pick = order[np.clip((q * fleet.size).astype(int), 0, fleet.size - 1)]
    base = AnalysisConfig.default(U=U, L=L, R=R, T_max=T_max, eta0=eta0,
                                  eta_decay=eta_decay, seed=seed)
    return dataclasses.replace(base, P=fleet.P[pick].copy(),
                               B=fleet.B[pick].copy())


class FleetCohortSource:
    """Per-round availability draw -> cohort sample -> policy view -> the
    sampled cohort's shards stacked at a fixed ``n_pad``."""

    def __init__(self, fleet: Fleet, availability: AvailabilityModel,
                 data: FleetData, ref: AnalysisConfig, *, cohort_size: int,
                 strategy: str = "uniform", seed: int = 0):
        self.fleet = fleet
        self.availability = availability
        self.data = data
        self.ref = ref
        self.cohort_size = int(cohort_size)
        self.strategy = strategy
        self.rng = np.random.default_rng([2077, seed])
        self._last_avail: Optional[np.ndarray] = None
        availability.reset()

    @property
    def plan_rate_max(self) -> float:
        """Fastest compute rate any cohort can plan for — bounds a
        re-solve's m so batches stay within the probed ``s_max`` even when
        the fleet's fastest devices were offline at re-plan time."""
        return float(self.fleet.P.max())

    def round_cohort(self, t: int) -> Optional[Cohort]:
        avail = self.availability.step(t)
        self._last_avail = avail
        idx = sample_cohort(self.rng, avail, self.fleet, self.cohort_size,
                            self.strategy)
        if len(idx) == 0:
            return None
        view = cohort_view(self.ref, self.fleet, idx)
        xs, ys, counts = stack_clients(self.data.x, self.data.y,
                                       [self.data.parts[u] for u in idx],
                                       n_pad=self.data.n_pad)
        return Cohort(x=xs, y=ys, counts=counts, view=view,
                      available=int(avail.sum()))

    # ------------------------------------------------------------------
    def replan_view(self, t: int, budget_left: float,
                    eta_tail) -> AnalysisConfig:
        """Remaining-horizon planning config re-estimated from the fleet's
        currently-reachable population (the online re-planning hook).

        ``U_round`` carries the availability model's expected-reachable
        forecast for every remaining round (clipped to the plannable cohort
        size), so the re-solve steers deadline budget into the rounds that
        will run with few contributors; ``U`` is its mean, and ``P``/``B``
        are quantile-spaced over the devices reachable in the current round
        (falling back to the whole fleet before the first draw) — tracking
        both how MANY devices the rounds can plan for and WHICH compute-rate
        spread they bring.
        """
        eta_tail = np.asarray(eta_tail, np.float32)
        rounds_left = len(eta_tail)
        exp = self.availability.expected_reachable(t, rounds_left)
        U_round = np.clip(np.round(exp), 2.0,
                          float(self.cohort_size)).astype(np.float32)
        U_est = int(np.clip(round(float(U_round.mean())), 2,
                            self.cohort_size))
        pool = (np.flatnonzero(self._last_avail)
                if self._last_avail is not None and self._last_avail.any()
                else np.arange(self.fleet.size))
        q = (np.arange(U_est) + 0.5) / U_est
        order = pool[np.argsort(self.fleet.P[pool])]
        pick = order[np.clip((q * len(order)).astype(int), 0,
                             len(order) - 1)]
        sigma2 = np.full((U_est,), float(np.mean(self.ref.sigma2)),
                         np.float32)
        return dataclasses.replace(
            self.ref, U=U_est, R=rounds_left, T_max=float(budget_left),
            eta=eta_tail, P=self.fleet.P[pick].copy(),
            B=self.fleet.B[pick].copy(), sigma2=sigma2, U_round=U_round)


def run_fleet(model: ModelAPI, fleet: Fleet, availability: AvailabilityModel,
              data: FleetData, *, method: str = "adel", rounds: int = 20,
              cohort_size: int = 32, cohort_strategy: str = "uniform",
              exec: Optional[ExecSpec] = None,
              backend=None, chunk_size: Optional[int] = None, mesh=None,
              T_max: Optional[float] = None,
              eta0: float = 2.0, eta_decay: float = 1.0,
              solver: str = "adam", solver_steps: int = 600,
              local_iters: Optional[int] = None, l2: Optional[float] = None,
              s_max: Optional[int] = None, eval_every: int = 1,
              seed: int = 0, verbose: bool = False,
              replan=None, donate: Optional[bool] = None,
              compression=None, agg_impl: Optional[str] = None,
              eval_metrics=None, tracer=None) -> tuple:
    """Run up to ``rounds`` federated rounds against a simulated fleet.

    Returns ``(params, History)``; the History carries the same fields as
    :func:`repro.fl.server.run_federated` plus per-round reachable-device
    counts, so ``benchmarks/report.py`` consumes it unchanged.

    HOW rounds execute is one :class:`repro.fl.spec.ExecSpec` (``exec=``),
    resolved against this front-end's base spec (``backend="chunked"``);
    the individual ``backend`` / ``chunk_size`` / ``mesh`` / ``donate`` /
    ``compression`` / ``agg_impl`` kwargs remain as deprecated aliases —
    both forms funnel through :meth:`ExecSpec.resolve` and give
    bit-identical trajectories. The chunked backend's chunk is clamped to
    the cohort size; the buffered backend's staleness knobs (``lam`` /
    ``max_age`` / ``buffer_cap``) ride on the spec. The spec's
    ``compression`` is also priced into the Problem-2 planning config
    (``comm_scale``) before solving.

    ``replan`` (None | trigger name | ``repro.core.replan.ReplanConfig``)
    enables availability-aware online re-solving of the remaining-horizon
    Problem 2 (``method="adel"`` only): the trigger watches the reachable
    count, and each re-solve re-estimates ``(U, P, B)`` from the currently-
    reachable population via :meth:`FleetCohortSource.replan_view`.
    ``eval_metrics`` (``(model, params, test_x, test_y) -> (metric,
    loss)``) overrides the classification accuracy default — pass
    :func:`repro.fl.tasks.lm_eval_metrics` with
    :func:`repro.fl.tasks.lm_fleet_data` to run LM workloads against the
    fleet. ``tracer`` (:class:`repro.obs.Tracer`) enables structured
    telemetry — phase spans, counters, and the per-round clock-model
    ledger summarized into ``History.telemetry``.
    """
    if fleet.size != len(data.parts):
        raise ValueError(f"fleet size {fleet.size} != data shards "
                         f"{len(data.parts)}")
    if availability.n != fleet.size:
        raise ValueError(f"availability model over {availability.n} devices "
                         f"!= fleet size {fleet.size}")
    if T_max is None:
        # same calibration as the seed benchmarks: avg depth ~50% of layers
        T_max = rounds * model.L * 0.5

    spec = ExecSpec.resolve(exec, base=ExecSpec(backend="chunked"),
                            backend=backend, chunk_size=chunk_size,
                            mesh=mesh, local_iters=local_iters, l2=l2,
                            donate=donate, compression=compression,
                            agg_impl=agg_impl)
    if spec.backend == "chunked":
        spec = dataclasses.replace(
            spec, chunk_size=min(spec.chunk_size, cohort_size))

    ref = reference_config(fleet, U=cohort_size, L=model.L, R=rounds,
                           T_max=T_max, eta0=eta0, eta_decay=eta_decay,
                           seed=seed)
    comp = spec.compression
    if comp.mode != "none":
        # price the compressed wire into the Problem-2 planning config
        # BEFORE solving: every B_u shrinks by the wire ratio (B_eff), so
        # the solver trades the freed deadline budget for larger batches.
        # bytes_full (dense f32 payload per client) feeds the
        # core.cost.upload_bytes diagnostic; derived views
        # (cohort_view / replan_view) inherit both via dataclasses.replace.
        try:
            sds = jax.eval_shape(model.init,
                                 jax.ShapeDtypeStruct((2,), np.uint32))
            n_params = sum(int(np.prod(l.shape))
                           for l in jax.tree.leaves(sds))
        except Exception:       # exotic init signatures: diagnostic only
            n_params = 0
        ref = dataclasses.replace(ref, comm_scale=comp.wire_scale(),
                                  bytes_full=4.0 * n_params)
    schedule = None
    if method == "adel":
        schedule = solve(ref, solver,
                         **({"steps": solver_steps} if solver == "adam" else {}))
    policy: Policy = make_policy(method, ref, schedule=schedule)

    if s_max is None:
        # probe against a synthetic best-case device (fleet-max P, fleet-min
        # B): per-device batch sizes (ADEL's B3) grow with P_u and shrink
        # with B_u, and the baselines' fixed batch uses the cohort MEANS —
        # both are maximized by this one-device view, so no realized cohort
        # (power-of-choice top picks, or a lucky tiny cohort under churn)
        # can plan a batch that sample_client_batches would silently clip
        view_best = dataclasses.replace(
            ref, U=1, P=np.asarray([fleet.P.max()], np.float32),
            B=np.asarray([fleet.B.min()], np.float32),
            sigma2=np.asarray([float(np.mean(ref.sigma2))], np.float32))
        # memory bound: batches are drawn with replacement, so allow up to
        # 4x the largest shard before clipping a (rare) extreme plan — every
        # client pays O(s_max) delta compute, and an unbounded best-case
        # bound would let one outlier device size the whole round's batch
        s_max = min(probe_s_max(policy, rounds, view=view_best),
                    4 * data.n_pad)
    s_max = max(s_max, 2)

    runtime = RoundRuntime(model, policy, exec=spec, tracer=tracer)
    source = FleetCohortSource(fleet, availability, data, ref,
                               cohort_size=cohort_size,
                               strategy=cohort_strategy, seed=seed)
    test_x, test_y = jnp.asarray(data.x_test), jnp.asarray(data.y_test)
    eval_fn = (None if eval_metrics is None else
               (lambda params: eval_metrics(model, params, test_x, test_y)))
    return runtime.run(source, rounds=rounds, T_max=T_max, eta=ref.eta,
                       s_max=s_max, key=jax.random.PRNGKey(seed),
                       test_x=test_x, test_y=test_y,
                       eval_every=eval_every, verbose=verbose,
                       method=f"fleet-{policy.name}", replan=replan,
                       eval_fn=eval_fn)
