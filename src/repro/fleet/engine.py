"""``run_fleet``: the fleet-scale front-end over the unified round runtime.

``run_fleet`` decouples the *population* (up to millions of devices, via
the :class:`repro.fleet.population.Population` protocol) from the *cohort*
(the ``U`` clients a round plans for) and is a thin wrapper:

1. it builds the Problem-2 planning config (:func:`reference_config`, from
   the population's ``plan_profile``) and the policy, probes ``s_max``
   against the population's best-case device, and
2. wraps the population's per-round cohort draws + view derivation in a
   :class:`FleetCohortSource`, then hands the loop to
   :class:`repro.fl.runtime.RoundRuntime`.

Per round the population decides who is reachable and picks at most
``cohort_size`` devices (``Population.sample_cohort``), the source
re-derives the AnalysisConfig the policy sees (``profile_view``) and
stacks only the sampled cohort's shards at a fixed ``n_pad`` — never a
``(fleet, N, ...)`` array. Device ids map onto data shards by ``id %
len(parts)`` (identity for materialized fleets sized to their data), so a
million-device :class:`~repro.fleet.population.ParametricPopulation` can
train against a bounded shard set with O(cohort) per-round cost. The
runtime pads the cohort axis to the execution backend's fixed width and
runs the round on any :mod:`repro.fl.backends` backend: ``chunked``
(default here — sequential software psum via ``aggregate_grads_chunk``),
``dense``, ``shard_map``, or ``hierarchical`` (edge-region partials +
global Eq. 5 fold, fed by the cohort's region ids). HeteroFL width masks
flow through all of them.

The legacy ``run_fleet(model, fleet, availability, data)`` positional
signature remains as a deprecated alias resolved onto
``MaterializedPopulation`` (bit-identical trajectories; warns, or raises
under ``REPRO_EXEC_STRICT=1`` — the same strictness toggle as
:meth:`repro.fl.spec.ExecSpec.validate`).
"""
from __future__ import annotations

import dataclasses
import os
import warnings
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.baselines import Policy, make_policy
from repro.core.scheduler import solve
from repro.core.types import AnalysisConfig
from repro.fl.partition import dirichlet_partition, iid_partition, stack_clients
from repro.fl.runtime import Cohort, ModelAPI, RoundRuntime, probe_s_max
from repro.fl.spec import ExecSpec
from repro.fleet.availability import AvailabilityModel
from repro.fleet.cohort import profile_view
from repro.fleet.population import (MaterializedPopulation, Population,
                                    PopulationSpec, make_population)
from repro.fleet.profiles import Fleet

__all__ = ["FleetData", "FleetCohortSource", "partition_fleet",
           "reference_config", "run_fleet"]


def _legacy_fleet_shim(population, availability, data, *,
                       where: str) -> tuple:
    """Resolve the deprecated ``(fleet, availability, data)`` calling form
    onto a :class:`MaterializedPopulation` (warn; raise in strict mode)."""
    if isinstance(population, Fleet):
        msg = (f"{where}(model, fleet, availability, data) is deprecated; "
               f"pass a Population — e.g. MaterializedPopulation(fleet, "
               f"availability) or make_population(spec) — followed by data")
        if bool(os.environ.get("REPRO_EXEC_STRICT")):
            raise ValueError(f"{msg} (REPRO_EXEC_STRICT=1)")
        warnings.warn(msg, DeprecationWarning, stacklevel=3)
        population = MaterializedPopulation(population, availability)
        availability = None
    elif isinstance(population, (str, dict, PopulationSpec)):
        population = make_population(population)
    if availability is not None and data is None:
        # new positional form: (model, population, data)
        data, availability = availability, None
    if availability is not None:
        raise TypeError(f"{where}: availability is part of the Population "
                        f"(wrap it in MaterializedPopulation)")
    return population, data


@dataclasses.dataclass
class FleetData:
    """Dataset + per-device shard indices (never stacked fleet-wide).

    ``parts[u]`` indexes device u's samples inside the shared ``x``/``y``
    arrays; only the per-round cohort is ever materialized as a stacked
    ``(U, n_pad, ...)`` batch.
    """

    x: np.ndarray                 # (n, ...) training inputs
    y: np.ndarray                 # (n,) training labels
    parts: list                   # len == fleet.size, index arrays into x/y
    x_test: np.ndarray
    y_test: np.ndarray

    @property
    def n_pad(self) -> int:
        return max(len(p) for p in self.parts)


def partition_fleet(x: np.ndarray, y: np.ndarray, x_test: np.ndarray,
                    y_test: np.ndarray, n_devices: int, *,
                    alpha: Optional[float] = 0.5, seed: int = 0) -> FleetData:
    """Split one dataset over ``n_devices`` shards (Dirichlet or IID)."""
    if alpha is None:
        parts = iid_partition(len(y), n_devices, seed=seed)
    else:
        parts = dirichlet_partition(y, n_devices, alpha=alpha, seed=seed)
    return FleetData(x=x, y=y, parts=parts, x_test=x_test, y_test=y_test)


def reference_config(population: Union[Population, Fleet], *, U: int, L: int,
                     R: int, T_max: float, eta0: float = 2.0,
                     eta_decay: float = 1.0, seed: int = 0) -> AnalysisConfig:
    """Planning config for the Problem-2 solver: a quantile-spaced
    representative cohort of the population (so the schedule reflects the
    real P/B spread rather than one random draw).

    Accepts a :class:`~repro.fleet.population.Population` (via its
    ``plan_profile``) or, for backward compatibility, a bare
    :class:`Fleet` — the pick math is identical either way.
    """
    if isinstance(population, Fleet):
        population = MaterializedPopulation(population)
    P, B = population.plan_profile(int(U))
    base = AnalysisConfig.default(U=U, L=L, R=R, T_max=T_max, eta0=eta0,
                                  eta_decay=eta_decay, seed=seed)
    return dataclasses.replace(base, P=P, B=B)


class FleetCohortSource:
    """Per-round population cohort draw -> policy view -> the sampled
    cohort's shards stacked at a fixed ``n_pad``.

    Accepts any :class:`~repro.fleet.population.Population`; the legacy
    ``FleetCohortSource(fleet, availability, data, ref)`` positional form
    is a deprecated alias resolved onto ``MaterializedPopulation`` with
    identical draw sequences. Device ids index data shards modulo
    ``len(data.parts)``, so a population larger than the shard count
    virtually re-shards (identity mapping when they match).
    """

    def __init__(self, population: Union[Population, Fleet],
                 availability: Optional[AvailabilityModel] = None,
                 data: Optional[FleetData] = None,
                 ref: Optional[AnalysisConfig] = None, *, cohort_size: int,
                 strategy: str = "uniform", seed: int = 0):
        if (not isinstance(population, Fleet)
                and isinstance(availability, FleetData) and ref is None):
            # new positional form (population, data, ref): shift the
            # operands out of the legacy (availability, data, ref) slots
            availability, data, ref = None, availability, data
        population, data = _legacy_fleet_shim(population, availability, data,
                                              where="FleetCohortSource")
        self.population: Population = population
        self.data = data
        self.ref = ref
        self.cohort_size = int(cohort_size)
        self.strategy = strategy
        self.rng = np.random.default_rng([2077, seed])
        population.reset()

    @property
    def plan_rate_max(self) -> float:
        """Fastest compute rate any cohort can plan for — bounds a
        re-solve's m so batches stay within the probed ``s_max`` even when
        the fleet's fastest devices were offline at re-plan time."""
        return float(self.population.rate_max)

    def round_cohort(self, t: int) -> Optional[Cohort]:
        draw = self.population.sample_cohort(t, self.rng,
                                             U=self.cohort_size,
                                             strategy=self.strategy)
        if draw is None:
            return None
        view = profile_view(self.ref, draw.P, draw.B)
        n_parts = len(self.data.parts)
        xs, ys, counts = stack_clients(
            self.data.x, self.data.y,
            [self.data.parts[int(u) % n_parts] for u in draw.ids],
            n_pad=self.data.n_pad)
        return Cohort(x=xs, y=ys, counts=counts, view=view,
                      available=draw.available, regions=draw.region)

    # ------------------------------------------------------------------
    def replan_view(self, t: int, budget_left: float,
                    eta_tail) -> AnalysisConfig:
        """Remaining-horizon planning config re-estimated from the
        currently-reachable population (the online re-planning hook).

        ``U_round`` carries the population's expected-reachable forecast
        for every remaining round (clipped to the plannable cohort size),
        so the re-solve steers deadline budget into the rounds that will
        run with few contributors; ``U`` is its mean, and ``P``/``B`` come
        from ``Population.replan_profile`` — quantile-spaced over the
        devices reachable in the current round for materialized
        populations, the fitted reference spread for parametric ones.
        """
        eta_tail = np.asarray(eta_tail, np.float32)
        rounds_left = len(eta_tail)
        exp = self.population.expected_reachable(t, rounds_left)
        U_round = np.clip(np.round(exp), 2.0,
                          float(self.cohort_size)).astype(np.float32)
        U_est = int(np.clip(round(float(U_round.mean())), 2,
                            self.cohort_size))
        P, B = self.population.replan_profile(U_est)
        sigma2 = np.full((U_est,), float(np.mean(self.ref.sigma2)),
                         np.float32)
        return dataclasses.replace(
            self.ref, U=U_est, R=rounds_left, T_max=float(budget_left),
            eta=eta_tail, P=P, B=B, sigma2=sigma2, U_round=U_round)


def run_fleet(model: ModelAPI, population: Union[Population, Fleet, str,
                                                 dict, PopulationSpec] = None,
              availability: Optional[AvailabilityModel] = None,
              data: Optional[FleetData] = None, *,
              fleet: Optional[Fleet] = None,
              method: str = "adel", rounds: int = 20,
              cohort_size: int = 32, cohort_strategy: str = "uniform",
              exec: Optional[ExecSpec] = None,
              backend=None, chunk_size: Optional[int] = None, mesh=None,
              T_max: Optional[float] = None,
              eta0: float = 2.0, eta_decay: float = 1.0,
              solver: str = "adam", solver_steps: int = 600,
              local_iters: Optional[int] = None, l2: Optional[float] = None,
              s_max: Optional[int] = None, eval_every: int = 1,
              seed: int = 0, verbose: bool = False,
              replan=None, donate: Optional[bool] = None,
              compression=None, agg_impl: Optional[str] = None,
              eval_metrics=None, tracer=None) -> tuple:
    """Run up to ``rounds`` federated rounds against a simulated population.

    Returns ``(params, History)``; the History carries the same fields as
    :func:`repro.fl.server.run_federated` plus per-round reachable-device
    counts, so ``benchmarks/report.py`` consumes it unchanged.

    WHO the rounds run against is one
    :class:`repro.fleet.population.Population` — ``run_fleet(model,
    population, data)`` — or anything
    :func:`repro.fleet.population.make_population` accepts (a spec string
    such as ``"parametric:longtail-mobile"``, a dict, a
    ``PopulationSpec``). The legacy ``run_fleet(model, fleet,
    availability, data)`` positional signature and the ``fleet=`` kwarg
    remain as deprecated aliases resolved onto
    ``MaterializedPopulation(fleet, availability)`` with bit-identical
    trajectories (DeprecationWarning; ValueError under
    ``REPRO_EXEC_STRICT=1``). Device ids index ``data.parts`` modulo the
    shard count, so parametric million-device populations train against a
    bounded shard set.

    HOW rounds execute is one :class:`repro.fl.spec.ExecSpec` (``exec=``),
    resolved against this front-end's base spec (``backend="chunked"``);
    the individual ``backend`` / ``chunk_size`` / ``mesh`` / ``donate`` /
    ``compression`` / ``agg_impl`` kwargs remain as deprecated aliases —
    both forms funnel through :meth:`ExecSpec.resolve` and give
    bit-identical trajectories. The chunked backend's chunk is clamped to
    the cohort size; the buffered backend's staleness knobs (``lam`` /
    ``max_age`` / ``buffer_cap``) and the hierarchical backend's
    ``regions`` fallback ride on the spec (cohort region ids from the
    population take precedence). The spec's ``compression`` is also
    priced into the Problem-2 planning config (``comm_scale``) before
    solving.

    ``replan`` (None | trigger name | ``repro.core.replan.ReplanConfig``)
    enables availability-aware online re-solving of the remaining-horizon
    Problem 2 (``method="adel"`` only): the trigger watches the reachable
    count, and each re-solve re-estimates ``(U, P, B)`` from the currently-
    reachable population via :meth:`FleetCohortSource.replan_view`.
    ``eval_metrics`` (``(model, params, test_x, test_y) -> (metric,
    loss)``) overrides the classification accuracy default — pass
    :func:`repro.fl.tasks.lm_eval_metrics` with
    :func:`repro.fl.tasks.lm_fleet_data` to run LM workloads against the
    fleet. ``tracer`` (:class:`repro.obs.Tracer`) enables structured
    telemetry — phase spans, counters, and the per-round clock-model
    ledger summarized into ``History.telemetry``.
    """
    if fleet is not None:
        if population is not None:
            raise TypeError("run_fleet: pass either population or the "
                            "deprecated fleet=, not both")
        population = fleet
    population, data = _legacy_fleet_shim(population, availability, data,
                                          where="run_fleet")
    if data is None or not len(data.parts):
        raise ValueError("run_fleet: data must be a FleetData with at least "
                         "one shard")
    if T_max is None:
        # same calibration as the seed benchmarks: avg depth ~50% of layers
        T_max = rounds * model.L * 0.5

    spec = ExecSpec.resolve(exec, base=ExecSpec(backend="chunked"),
                            backend=backend, chunk_size=chunk_size,
                            mesh=mesh, local_iters=local_iters, l2=l2,
                            donate=donate, compression=compression,
                            agg_impl=agg_impl)
    if spec.backend == "chunked":
        spec = dataclasses.replace(
            spec, chunk_size=min(spec.chunk_size, cohort_size))

    ref = reference_config(population, U=cohort_size, L=model.L, R=rounds,
                           T_max=T_max, eta0=eta0, eta_decay=eta_decay,
                           seed=seed)
    comp = spec.compression
    if comp.mode != "none":
        # price the compressed wire into the Problem-2 planning config
        # BEFORE solving: every B_u shrinks by the wire ratio (B_eff), so
        # the solver trades the freed deadline budget for larger batches.
        # bytes_full (dense f32 payload per client) feeds the
        # core.cost.upload_bytes diagnostic; derived views
        # (cohort_view / replan_view) inherit both via dataclasses.replace.
        try:
            sds = jax.eval_shape(model.init,
                                 jax.ShapeDtypeStruct((2,), np.uint32))
            n_params = sum(int(np.prod(l.shape))
                           for l in jax.tree.leaves(sds))
        except Exception:       # exotic init signatures: diagnostic only
            n_params = 0
        ref = dataclasses.replace(ref, comm_scale=comp.wire_scale(),
                                  bytes_full=4.0 * n_params)
    schedule = None
    if method == "adel":
        schedule = solve(ref, solver,
                         **({"steps": solver_steps} if solver == "adam" else {}))
    policy: Policy = make_policy(method, ref, schedule=schedule)

    if s_max is None:
        # probe against a synthetic best-case device (population-max P,
        # population-min B): per-device batch sizes (ADEL's B3) grow with
        # P_u and shrink with B_u, and the baselines' fixed batch uses the
        # cohort MEANS — both are maximized by this one-device view, so no
        # realized cohort (power-of-choice top picks, or a lucky tiny
        # cohort under churn) can plan a batch that sample_client_batches
        # would silently clip
        P_best, B_best = population.best_profile()
        view_best = dataclasses.replace(
            ref, U=1, P=np.asarray([P_best], np.float32),
            B=np.asarray([B_best], np.float32),
            sigma2=np.asarray([float(np.mean(ref.sigma2))], np.float32))
        # memory bound: batches are drawn with replacement, so allow up to
        # 4x the largest shard before clipping a (rare) extreme plan — every
        # client pays O(s_max) delta compute, and an unbounded best-case
        # bound would let one outlier device size the whole round's batch
        s_max = min(probe_s_max(policy, rounds, view=view_best),
                    4 * data.n_pad)
    s_max = max(s_max, 2)

    runtime = RoundRuntime(model, policy, exec=spec, tracer=tracer)
    source = FleetCohortSource(population, data=data, ref=ref,
                               cohort_size=cohort_size,
                               strategy=cohort_strategy, seed=seed)
    test_x, test_y = jnp.asarray(data.x_test), jnp.asarray(data.y_test)
    eval_fn = (None if eval_metrics is None else
               (lambda params: eval_metrics(model, params, test_x, test_y)))
    return runtime.run(source, rounds=rounds, T_max=T_max, eta=ref.eta,
                       s_max=s_max, key=jax.random.PRNGKey(seed),
                       test_x=test_x, test_y=test_y,
                       eval_every=eval_every, verbose=verbose,
                       method=f"fleet-{policy.name}", replan=replan,
                       eval_fn=eval_fn)
