"""The ``Population`` protocol: fleet size as a parameter, not an array.

The original fleet surface materialized one ``(n,)`` row per device
(:class:`repro.fleet.profiles.Fleet`) and evaluated availability over the
WHOLE population every round, capping simulations at thousands of
devices. The Problem-2 solver, however, only ever consumes cohort-level
``(P_u, B_u)`` statistics — so this module makes the population an
*interface* with two implementations:

* :class:`MaterializedPopulation` — wraps today's ``Fleet`` arrays plus an
  :class:`repro.fleet.availability.AvailabilityModel` **bit-for-bit**: the
  per-round RNG consumption is exactly the sequence the legacy
  ``FleetCohortSource`` performed, so existing scenario trajectories (and
  the committed ``fleet_smoke`` baselines) reproduce exactly through the
  new API.
* :class:`ParametricPopulation` — draws device profiles *lazily* from
  per-tier distributions fitted to a small reference draw of the preset
  (the same ``P_q05_50_95``/``B_q05_50_95`` quantiles ``fleet_smoke``
  records), and evaluates availability analytically for the sampled
  cohort only. Per-round cost is O(cohort): no array anywhere is sized by
  the fleet, so ``size=1_000_000`` costs the same per round as
  ``size=10_000`` (see ``benchmarks/fleet_scale.py``).

Construction funnels through :class:`PopulationSpec` /
:func:`make_population`, the population analogue of
:class:`repro.fl.spec.ExecSpec`: a frozen spec with ``resolve`` /
``add_cli_args`` / ``from_cli`` so ``python -m repro.fleet.scenarios`` and
``launch/train.py`` share one ``--population`` flag block. Source forms::

    "longtail-mobile"              # materialized preset draw
    "trace:PATH"                   # materialized JSON device trace
    "mobiperf:PATH"                # materialized MobiPerf measurement log
    "parametric:longtail-mobile"   # lazy million-device sampling

``regions`` partitions every sampled cohort into edge regions (device id
mod ``regions``); the ids flow through :class:`repro.fl.runtime.Cohort`
into the ``hierarchical`` execution backend's two-tier region -> global
aggregation fold (:class:`repro.fl.backends.HierarchicalBackend`).
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import warnings
from typing import Optional

import numpy as np

from repro.fleet.availability import (AVAILABILITY, AlwaysOn,
                                      AvailabilityModel, make_availability)
from repro.fleet.cohort import COHORT_STRATEGIES, _stratified, sample_cohort
from repro.fleet.profiles import (PRESETS, Fleet, load_mobiperf, load_trace,
                                  make_fleet)

__all__ = ["CohortDraw", "Population", "MaterializedPopulation",
           "ParametricPopulation", "PopulationSpec", "make_population"]

# z-score of the 0.95 quantile of the standard normal: the two-piece
# lognormal fits pin (q05, q50, q95) exactly through this constant
_Z95 = 1.6448536269514722


@dataclasses.dataclass(frozen=True)
class CohortDraw:
    """One round's sampled cohort: device ids + their profiles.

    ``region`` is the edge-region id of every cohort member (``ids %
    population.regions``) or ``None`` when the population is flat — it
    rides :class:`repro.fl.runtime.Cohort` into the hierarchical backend.
    """

    ids: np.ndarray                       # (U,) int64 device ids
    P: np.ndarray                         # (U,) float32 compute rates (B1)
    B: np.ndarray                         # (U,) float32 network times (B2)
    tier: np.ndarray                      # (U,) int32 memory tiers
    available: int                        # reachable-device count this round
    region: Optional[np.ndarray] = None   # (U,) int32 edge-region ids

    @property
    def size(self) -> int:
        return int(self.ids.shape[0])


class Population:
    """Protocol for device populations: everything ``run_fleet`` needs.

    Implementations answer per-round cohort draws and cohort-level
    planning statistics WITHOUT promising per-device arrays — fleet size
    is a parameter. The contract:

    * ``size`` — number of simulated devices.
    * ``regions`` — edge-region count for hierarchical aggregation
      (device id mod ``regions``; 1 = flat).
    * ``sample_cohort(t, rng, U=, strategy=)`` — availability draw +
      cohort pick for round ``t``; returns a :class:`CohortDraw` or
      ``None`` when nobody is reachable. ``rng`` is the CALLER's cohort
      stream (``default_rng([2077, seed])`` in ``FleetCohortSource``) so
      draw sequences stay bit-compatible with the legacy path.
    * ``plan_profile(U)`` — quantile-spaced representative ``(P, B)``
      arrays for the Problem-2 planning config
      (:func:`repro.fleet.engine.reference_config`).
    * ``replan_profile(U)`` — like ``plan_profile`` but conditioned on
      the most recent availability information (the online re-planning
      hook).
    * ``best_profile()`` — ``(P_max, B_min)`` of the population, for the
      ``s_max`` memory probe.
    * ``expected_reachable(t0, horizon)`` — expected reachable counts for
      the next ``horizon`` rounds (re-planning forecasts).
    * ``rate_max`` — fastest plannable compute rate.
    * ``plan_stats()`` / ``describe()`` — quantile summaries.
    * ``reset()`` — rewind any availability state.
    """

    regions: int = 1

    @property
    def size(self) -> int:  # pragma: no cover - interface
        raise NotImplementedError

    def sample_cohort(self, t: int, rng: np.random.Generator, *, U: int,
                      strategy: str = "uniform") -> Optional[CohortDraw]:
        raise NotImplementedError  # pragma: no cover - interface

    def plan_profile(self, U: int) -> tuple[np.ndarray, np.ndarray]:
        raise NotImplementedError  # pragma: no cover - interface

    def replan_profile(self, U: int) -> tuple[np.ndarray, np.ndarray]:
        return self.plan_profile(U)

    def best_profile(self) -> tuple[float, float]:
        raise NotImplementedError  # pragma: no cover - interface

    def expected_reachable(self, t0: int, horizon: int = 1) -> np.ndarray:
        raise NotImplementedError  # pragma: no cover - interface

    @property
    def rate_max(self) -> float:
        return float(self.best_profile()[0])

    def reset(self) -> None:
        pass

    def describe(self) -> dict:  # pragma: no cover - interface
        raise NotImplementedError

    def plan_stats(self) -> dict:
        """Cohort-planning quantile summary (P/B q05/q50/q95 + tiers)."""
        return self.describe()["fleet"]

    def _region_ids(self, ids: np.ndarray) -> Optional[np.ndarray]:
        if self.regions <= 1:
            return None
        return (np.asarray(ids, np.int64) % self.regions).astype(np.int32)


class MaterializedPopulation(Population):
    """Today's ``Fleet`` arrays + availability model behind ``Population``.

    Per-round behaviour is BIT-FOR-BIT the legacy ``FleetCohortSource``
    sequence: one ``availability.step(t)`` over the full fleet, then one
    :func:`repro.fleet.cohort.sample_cohort` draw from the caller's RNG —
    so every pre-existing scenario trajectory (including the committed
    ``fleet_smoke`` baselines) reproduces exactly through the new API.
    Memory and per-round cost stay O(fleet); use
    :class:`ParametricPopulation` beyond ~10^5 devices.
    """

    def __init__(self, fleet: Fleet,
                 availability: Optional[AvailabilityModel] = None, *,
                 regions: int = 1):
        if availability is None:
            availability = AlwaysOn(fleet.size)
        if availability.n != fleet.size:
            raise ValueError(
                f"availability model over {availability.n} devices != fleet "
                f"size {fleet.size}")
        self.fleet = fleet
        self.availability = availability
        self.regions = max(int(regions), 1)
        self._last_avail: Optional[np.ndarray] = None

    @property
    def size(self) -> int:
        return self.fleet.size

    def reset(self) -> None:
        self.availability.reset()
        self._last_avail = None

    def sample_cohort(self, t: int, rng: np.random.Generator, *, U: int,
                      strategy: str = "uniform") -> Optional[CohortDraw]:
        avail = self.availability.step(t)
        self._last_avail = avail
        idx = sample_cohort(rng, avail, self.fleet, int(U), strategy)
        if len(idx) == 0:
            return None
        ids = np.asarray(idx, np.int64)
        return CohortDraw(ids=ids, P=self.fleet.P[idx], B=self.fleet.B[idx],
                          tier=self.fleet.tier[idx],
                          available=int(avail.sum()),
                          region=self._region_ids(ids))

    def plan_profile(self, U: int) -> tuple[np.ndarray, np.ndarray]:
        q = (np.arange(U) + 0.5) / U
        order = np.argsort(self.fleet.P)
        pick = order[np.clip((q * self.fleet.size).astype(int), 0,
                             self.fleet.size - 1)]
        return self.fleet.P[pick].copy(), self.fleet.B[pick].copy()

    def replan_profile(self, U: int) -> tuple[np.ndarray, np.ndarray]:
        """Quantile-spaced over the devices reachable in the current round
        (falling back to the whole fleet before the first draw)."""
        pool = (np.flatnonzero(self._last_avail)
                if self._last_avail is not None and self._last_avail.any()
                else np.arange(self.fleet.size))
        q = (np.arange(U) + 0.5) / U
        order = pool[np.argsort(self.fleet.P[pool])]
        pick = order[np.clip((q * len(order)).astype(int), 0,
                             len(order) - 1)]
        return self.fleet.P[pick].copy(), self.fleet.B[pick].copy()

    def best_profile(self) -> tuple[float, float]:
        return float(self.fleet.P.max()), float(self.fleet.B.min())

    def expected_reachable(self, t0: int, horizon: int = 1) -> np.ndarray:
        return self.availability.expected_reachable(t0, horizon)

    def describe(self) -> dict:
        return {"fleet": self.fleet.describe(),
                "availability": self.availability.describe(),
                "regions": self.regions}


def _splitmix64(x: np.ndarray) -> np.ndarray:
    """Vectorized splitmix64 finalizer: uint64 array -> uint64 array."""
    x = x + np.uint64(0x9E3779B97F4A7C15)
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return x ^ (x >> np.uint64(31))


def _hash_uniform(h: np.ndarray, stream: int) -> np.ndarray:
    """One U(0,1) double per element from hash state ``h`` and a stream id."""
    mixed = _splitmix64(h ^ np.uint64(0xD6E8FEB86659FD93 * (stream + 1)
                                      & 0xFFFFFFFFFFFFFFFF))
    return (mixed >> np.uint64(11)).astype(np.float64) * (2.0 ** -53)


def _box_muller(u1: np.ndarray, u2: np.ndarray) -> np.ndarray:
    u1 = np.maximum(u1, 1e-300)
    return np.sqrt(-2.0 * np.log(u1)) * np.cos(2.0 * np.pi * u2)


@dataclasses.dataclass(frozen=True)
class _TwoPieceLogNormal:
    """Lognormal with separate spread below/above the median.

    ``mu = ln q50``; ``sigma_lo``/``sigma_hi`` are chosen so the fitted
    q05 and q95 equal the reference draw's — all three recorded quantiles
    match by construction, which is what the parametric-fidelity contract
    tests. Samples clip to the reference draw's observed [min, max].
    """

    mu: float
    sigma_lo: float
    sigma_hi: float
    lo: float
    hi: float

    @classmethod
    def fit(cls, vals: np.ndarray) -> "_TwoPieceLogNormal":
        q05, q50, q95 = np.quantile(vals, [0.05, 0.5, 0.95])
        mu = float(np.log(q50))
        return cls(mu=mu,
                   sigma_lo=max((mu - float(np.log(max(q05, 1e-12)))) / _Z95,
                                0.0),
                   sigma_hi=max((float(np.log(q95)) - mu) / _Z95, 0.0),
                   lo=float(vals.min()), hi=float(vals.max()))

    def sample(self, z: np.ndarray) -> np.ndarray:
        sigma = np.where(z < 0.0, self.sigma_lo, self.sigma_hi)
        return np.clip(np.exp(self.mu + sigma * z),
                       self.lo, self.hi).astype(np.float32)

    def quantiles(self) -> list:
        return [round(float(np.clip(np.exp(self.mu + s * z), self.lo,
                                    self.hi)), 4)
                for z, s in ((-_Z95, self.sigma_lo), (0.0, 0.0),
                             (_Z95, self.sigma_hi))]


class ParametricPopulation(Population):
    """Million-device populations with O(cohort) per-round cost.

    Instead of materializing ``(n,)`` profile arrays, the population keeps
    a small *reference draw* of the preset (``min(size, ref_size)``
    devices, same ``(preset, seed)`` determinism as :func:`make_fleet`)
    and fits, per memory tier, a :class:`_TwoPieceLogNormal` to ``P`` and
    ``B`` — pinning exactly the ``P_q05_50_95``/``B_q05_50_95`` quantiles
    the ``fleet_smoke`` baselines record. Everything per round is then
    cohort-sized:

    * **profiles** — device ``u``'s ``(tier, P_u, B_u)`` is a pure
      function of ``(seed, u)``: a vectorized splitmix64 hash yields the
      device's uniforms, Box-Muller turns them into the tier-conditional
      lognormal draws. Any device can be profiled on demand, and the same
      device always gets the same profile — no per-device state.
    * **availability** — the churn model's *marginal* rate ``r(t)``
      (:meth:`repro.fleet.availability.AvailabilityModel.marginal_rate`)
      prices reachability analytically: the reachable count is one
      ``Binomial(size, r(t))`` draw, and cohort membership is uniform
      over devices (per-device availability is exchangeable under the
      marginal model — Markov stickiness and per-device diurnal phases
      are deliberately averaged out; use :class:`MaterializedPopulation`
      when those correlations matter).
    * **cohort ids** — distinct ids come from rejection sampling
      (``rng.integers`` + ``np.unique`` top-up), never an O(size)
      permutation.

    All three cohort strategies work: ``power-of-choice`` and
    ``stratified`` profile an oversampled candidate pool lazily and
    select within it.
    """

    def __init__(self, preset: str, size: int, *, seed: int = 0,
                 availability: str = "always-on", availability_kwargs=(),
                 regions: int = 1, ref_size: int = 4096):
        if preset not in PRESETS:
            raise ValueError(f"unknown fleet preset {preset!r}; registered "
                             f"presets: {sorted(PRESETS)}")
        if availability not in AVAILABILITY:
            raise ValueError(
                f"unknown availability model {availability!r}; known: "
                f"{sorted(AVAILABILITY)}")
        self.preset = preset
        self._size = int(size)
        self.seed = int(seed)
        self.regions = max(int(regions), 1)
        self.availability_name = availability
        self.availability_kwargs = dict(availability_kwargs)
        self._avail_cls = AVAILABILITY[availability]
        self._ref = make_fleet(preset, min(self._size, int(ref_size)),
                               seed=seed)
        fracs = np.bincount(self._ref.tier, minlength=3) / self._ref.size
        self._tier_cum = np.cumsum(fracs)
        self._fit_P = [(_TwoPieceLogNormal.fit(self._ref.P[self._ref.tier == k])
                        if fracs[k] > 0 else None) for k in range(3)]
        self._fit_B = [(_TwoPieceLogNormal.fit(self._ref.B[self._ref.tier == k])
                        if fracs[k] > 0 else None) for k in range(3)]
        self._seed_hash = _splitmix64(
            np.asarray([seed], np.uint64) ^ np.uint64(0xA0761D6478BD642F))[0]

    @property
    def size(self) -> int:
        return self._size

    def _rate(self, t: int) -> float:
        return self._avail_cls.marginal_rate(t, **self.availability_kwargs)

    def profiles(self, ids) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Deterministic lazy profiles ``(P, B, tier)`` for device ids."""
        ids = np.asarray(ids, np.uint64)
        h = _splitmix64(ids ^ self._seed_hash)
        tier = np.searchsorted(self._tier_cum, _hash_uniform(h, 0),
                               side="right").astype(np.int32)
        tier = np.minimum(tier, 2)
        zP = _box_muller(_hash_uniform(h, 1), _hash_uniform(h, 2))
        zB = _box_muller(_hash_uniform(h, 3), _hash_uniform(h, 4))
        P = np.empty(len(ids), np.float32)
        B = np.empty(len(ids), np.float32)
        for k in range(3):
            sel = tier == k
            if not sel.any():
                continue
            fit_P, fit_B = self._fit_P[k], self._fit_B[k]
            if fit_P is None:            # empty reference tier: remap to the
                tier[sel] = 1            # middle tier's fit (never selected
                fit_P, fit_B = self._fit_P[1], self._fit_B[1]  # in practice)
            P[sel] = fit_P.sample(zP[sel])
            B[sel] = fit_B.sample(zB[sel])
        return P, B, tier

    def _distinct_ids(self, rng: np.random.Generator, k: int) -> np.ndarray:
        """``k`` distinct uniform device ids in O(k), never O(size)."""
        n = self._size
        if k >= n:
            return np.arange(n, dtype=np.int64)
        ids = np.unique(rng.integers(0, n, size=k + (k >> 2) + 8))
        while len(ids) < k:
            ids = np.unique(np.concatenate(
                [ids, rng.integers(0, n, size=k)]))
        if len(ids) > k:
            ids = np.sort(rng.choice(ids, size=k, replace=False))
        return ids.astype(np.int64)

    def sample_cohort(self, t: int, rng: np.random.Generator, *, U: int,
                      strategy: str = "uniform") -> Optional[CohortDraw]:
        r = float(np.clip(self._rate(t), 0.0, 1.0))
        available = int(rng.binomial(self._size, r)) if r > 0 else 0
        if available == 0:
            return None
        U = int(U)
        U_eff = min(U, available)
        if strategy == "uniform":
            ids = self._distinct_ids(rng, U_eff)
        elif strategy == "power-of-choice":
            k = min(available, 2 * U, self._size)
            cand = self._distinct_ids(rng, k)
            P_c, _, _ = self.profiles(cand)
            ids = np.sort(cand[np.argsort(-P_c)[:U_eff]])
        elif strategy == "stratified":
            k = min(available, max(4 * U_eff, U_eff), self._size)
            cand = self._distinct_ids(rng, k)
            _, _, tier_c = self.profiles(cand)
            pos = _stratified(rng, np.arange(len(cand)), tier_c, U_eff)
            ids = np.sort(cand[pos])
        else:
            raise ValueError(f"unknown cohort strategy {strategy!r}; "
                             f"known: {COHORT_STRATEGIES}")
        P, B, tier = self.profiles(ids)
        return CohortDraw(ids=ids, P=P, B=B, tier=tier, available=available,
                          region=self._region_ids(ids))

    def plan_profile(self, U: int) -> tuple[np.ndarray, np.ndarray]:
        """Quantile-spaced representative cohort over the reference draw
        (the same pick math as the materialized path, so planning configs
        agree between a preset's parametric and materialized forms)."""
        q = (np.arange(U) + 0.5) / U
        order = np.argsort(self._ref.P)
        pick = order[np.clip((q * self._ref.size).astype(int), 0,
                             self._ref.size - 1)]
        return self._ref.P[pick].copy(), self._ref.B[pick].copy()

    def best_profile(self) -> tuple[float, float]:
        # lazy draws clip to the reference draw's [min, max], so the
        # reference extremes bound every profile the population can emit
        return float(self._ref.P.max()), float(self._ref.B.min())

    def expected_reachable(self, t0: int, horizon: int = 1) -> np.ndarray:
        return np.asarray([self._size * float(np.clip(self._rate(t0 + k),
                                                      0.0, 1.0))
                           for k in range(horizon)])

    def describe(self) -> dict:
        fracs = np.diff(np.concatenate([[0.0], self._tier_cum]))
        fleet = {"name": f"parametric:{self.preset}", "size": self._size,
                 "P_q05_50_95": self._ref.describe()["P_q05_50_95"],
                 "B_q05_50_95": self._ref.describe()["B_q05_50_95"],
                 "tiers": [int(round(f * self._size)) for f in fracs]}
        avail = {"name": self.availability_name, "n": self._size,
                 "analytic": True, **self.availability_kwargs}
        return {"fleet": fleet, "availability": avail,
                "regions": self.regions}


_PFIELDS = ("source", "size", "availability", "availability_kwargs",
            "regions", "seed")
_SOURCE_FORMS = ("PRESET", "trace:PATH", "mobiperf:PATH", "parametric:PRESET")


@dataclasses.dataclass(frozen=True)
class PopulationSpec:
    """One immutable value describing WHO a simulation runs against.

    The population analogue of :class:`repro.fl.spec.ExecSpec`: front-ends
    (``run_fleet``, ``repro.fleet.scenarios``, ``launch/train.py``) accept
    a spec plus legacy per-field kwargs, funnel both through
    :meth:`resolve`, and share one CLI flag block via
    :meth:`add_cli_args` / :meth:`from_cli`.
    """

    source: str = "uniform"          # preset | trace:/mobiperf: | parametric:
    size: int = 500
    availability: str = "always-on"
    availability_kwargs: tuple = ()  # tuple of (key, value) pairs (hashable)
    regions: int = 1                 # edge regions (device id mod regions)
    seed: int = 0

    def __post_init__(self):
        if isinstance(self.availability_kwargs, dict):
            object.__setattr__(self, "availability_kwargs",
                               tuple(sorted(self.availability_kwargs.items())))
        if self.regions < 1:
            raise ValueError(f"regions must be >= 1, got {self.regions}")

    # -- resolution (mirrors ExecSpec.resolve) --------------------------
    @classmethod
    def resolve(cls, spec: Optional["PopulationSpec"] = None, *,
                base: Optional["PopulationSpec"] = None,
                **legacy) -> "PopulationSpec":
        """Overlay non-None legacy kwargs on ``spec`` (or ``base``)."""
        unknown = set(legacy) - set(_PFIELDS)
        if unknown:
            raise TypeError(f"unknown population kwargs {sorted(unknown)}; "
                            f"fields: {_PFIELDS}")
        out = spec if spec is not None else (base or cls())
        overrides = {k: v for k, v in legacy.items() if v is not None}
        if overrides:
            out = dataclasses.replace(out, **overrides)
        return out

    def validate(self, *, strict: Optional[bool] = None) -> "PopulationSpec":
        """Flag spec values the resolved population cannot honour.

        Warns by default; raises when ``strict`` (default: the
        ``REPRO_EXEC_STRICT`` env toggle, shared with ``ExecSpec``)."""
        if strict is None:
            strict = bool(os.environ.get("REPRO_EXEC_STRICT"))
        issues = []
        kind, _, arg = self.source.partition(":")
        if kind in ("trace", "mobiperf"):
            if not arg:
                issues.append(f"source {self.source!r} is missing its PATH")
            elif self.size != type(self).size:
                issues.append(f"size={self.size} is ignored for "
                              f"{kind}: sources (the file fixes the size)")
        if self.availability not in AVAILABILITY:
            issues.append(f"unknown availability model "
                          f"{self.availability!r}; known: "
                          f"{sorted(AVAILABILITY)}")
        if issues:
            msg = "PopulationSpec: " + "; ".join(issues)
            if strict:
                raise ValueError(msg + " (REPRO_EXEC_STRICT=1)")
            warnings.warn(msg, UserWarning, stacklevel=3)
        return self

    # -- construction ---------------------------------------------------
    def build(self, *, avail_seed: Optional[int] = None) -> Population:
        """Materialize/instantiate the population this spec describes.

        ``avail_seed`` optionally decouples the availability stream's seed
        from the profile seed (scenario front-ends seed availability with
        ``fc.seed + run_seed``, keeping legacy trajectories bit-exact).
        """
        kind, _, arg = self.source.partition(":")
        seed_a = self.seed if avail_seed is None else int(avail_seed)
        if kind == "parametric":
            if arg not in PRESETS:
                raise ValueError(
                    f"unknown parametric preset {arg!r}; registered presets: "
                    f"{sorted(PRESETS)}")
            return ParametricPopulation(
                arg, self.size, seed=self.seed,
                availability=self.availability,
                availability_kwargs=self.availability_kwargs,
                regions=self.regions)
        if kind == "trace" and arg:
            fleet = load_trace(arg)
        elif kind == "mobiperf" and arg:
            fleet = load_mobiperf(arg)
        elif self.source in PRESETS:
            fleet = make_fleet(self.source, self.size, seed=self.seed)
        else:
            raise ValueError(
                f"unknown population source {self.source!r}; expected one of "
                f"{_SOURCE_FORMS} with PRESET in {sorted(PRESETS)}")
        avail = make_availability(self.availability, fleet.size, seed=seed_a,
                                  **dict(self.availability_kwargs))
        return MaterializedPopulation(fleet, avail, regions=self.regions)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    # -- the shared CLI flag block (mirrors ExecSpec.add_cli_args) ------
    @staticmethod
    def add_cli_args(parser: argparse.ArgumentParser) -> None:
        """Install the shared ``--population`` flag block. All defaults are
        None so :meth:`from_cli` only overrides what the user set."""
        g = parser.add_argument_group(
            "population", "device population (repro.fleet.population); "
                          "unset flags keep the front-end's resolved spec")
        g.add_argument("--population", default=None, metavar="SRC",
                       help="population source: a fleet preset "
                            f"({', '.join(sorted(PRESETS))}), 'trace:PATH', "
                            "'mobiperf:PATH', or 'parametric:PRESET' "
                            "(lazy profiles, million-device scale)")
        g.add_argument("--fleet-size", type=int, default=None,
                       help="number of simulated devices")
        g.add_argument("--availability", default=None,
                       choices=sorted(AVAILABILITY),
                       help="availability/churn model")
        g.add_argument("--regions", type=int, default=None,
                       help="edge regions for hierarchical two-tier "
                            "aggregation (device id mod regions; 1 = flat)")

    @classmethod
    def from_cli(cls, args: argparse.Namespace, *,
                 base: Optional["PopulationSpec"] = None) -> "PopulationSpec":
        return cls.resolve(base=base, source=args.population,
                           size=args.fleet_size,
                           availability=args.availability,
                           regions=args.regions).validate()


def make_population(spec, *, size: Optional[int] = None,
                    seed: Optional[int] = None,
                    availability: Optional[str] = None,
                    availability_kwargs=None,
                    regions: Optional[int] = None,
                    avail_seed: Optional[int] = None) -> Population:
    """One factory for every population form (the ``spec`` front door).

    ``spec`` may be a :class:`Population` (returned as-is), a
    :class:`~repro.fleet.profiles.Fleet` (wrapped in a
    :class:`MaterializedPopulation`, availability built from the
    ``availability``/``availability_kwargs`` overrides), a
    :class:`PopulationSpec`, a dict of spec fields, or a source string
    (``"longtail-mobile"``, ``"trace:PATH"``, ``"mobiperf:PATH"``,
    ``"parametric:PRESET"``). Non-None keyword overrides overlay the spec
    via :meth:`PopulationSpec.resolve`.
    """
    if isinstance(spec, Population):
        return spec
    if isinstance(spec, Fleet):
        n_regions = 1 if regions is None else int(regions)
        avail = make_availability(availability or "always-on", spec.size,
                                  seed=(avail_seed if avail_seed is not None
                                        else (seed or 0)),
                                  **dict(availability_kwargs or {}))
        return MaterializedPopulation(spec, avail, regions=n_regions)
    if isinstance(spec, PopulationSpec):
        base = spec
    elif isinstance(spec, dict):
        base = PopulationSpec(**spec)
    elif isinstance(spec, str):
        base = PopulationSpec(source=spec)
    else:
        raise TypeError(f"make_population: unsupported spec type "
                        f"{type(spec).__name__}; expected Population, Fleet, "
                        f"PopulationSpec, dict, or source string")
    base = PopulationSpec.resolve(
        base=base, size=size, seed=seed, availability=availability,
        availability_kwargs=(tuple(sorted(availability_kwargs.items()))
                             if isinstance(availability_kwargs, dict)
                             else availability_kwargs),
        regions=regions)
    return base.build(avail_seed=avail_seed)
