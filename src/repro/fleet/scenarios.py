"""Named fleet scenarios: fleet preset x availability x partition x policy.

A scenario bundles a :class:`repro.configs.FleetConfig` (population +
cohort) with the data partition and the round policy, so every later PR
can say "run ADEL against ``longtail-mobile-diurnal``" and get the same
experiment. The CLI emits ``History`` dicts in the same JSON layout the
paper-figure benchmarks use, so ``benchmarks/report.py`` renders them.

    PYTHONPATH=src python -m repro.fleet.scenarios --list
    PYTHONPATH=src python -m repro.fleet.scenarios --run longtail-mobile-diurnal --rounds 5
    PYTHONPATH=src python -m repro.fleet.scenarios --run datacenter-always-on --save
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
from typing import Optional

from repro import obs
from repro.configs.base import (CompressionConfig, ExecSpec, FleetConfig,
                                ReplanConfig)
from repro.core.compression import make_compression
from repro.core.replan import TRIGGERS
from repro.data.synthetic import make_image_dataset
from repro.fleet.engine import partition_fleet, run_fleet
from repro.fleet.population import PopulationSpec
from repro.models.paper_models import make_cnn, make_mlp

__all__ = ["Scenario", "SCENARIOS", "get_scenario", "run_scenario"]

RESULTS_PATH = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "experiments", "results", "fleet_scenarios.json")


@dataclasses.dataclass(frozen=True)
class Scenario:
    name: str
    fleet: FleetConfig
    method: str = "adel"           # adel | salf | drop | wait
    model: str = "mlp"             # mlp | cnn | lm (reduced LM arch)
    alpha: Optional[float] = 0.5   # Dirichlet non-IID (None = IID)
    rounds: int = 20
    eta0: float = 2.0
    n_train: int = 4000
    n_test: int = 400
    arch: str = "qwen1.5-4b"       # model == "lm" only: the arch id
    note: str = ""


def _scn(name, preset, size, availability, akw=(), method="adel",
         strategy="uniform", alpha=0.5, note="", cohort=32,
         replan=ReplanConfig(), compression=CompressionConfig(),
         exec=None, population=None, regions=1, **kw) -> Scenario:
    return Scenario(
        name=name, method=method, alpha=alpha, note=note,
        fleet=FleetConfig(preset=preset, size=size, availability=availability,
                          availability_kwargs=tuple(akw),
                          cohort_strategy=strategy, cohort_size=cohort,
                          replan=replan, compression=compression,
                          exec=exec, population=population, regions=regions),
        **kw)


SCENARIOS = {s.name: s for s in [
    _scn("longtail-mobile-diurnal", "longtail-mobile", 600, "diurnal",
         akw=(("mean", 0.6), ("amplitude", 0.35), ("period", 12.0)),
         note="mass-market phones in time zones; ADEL under churny long tail"),
    _scn("datacenter-always-on", "datacenter", 512, "always-on",
         note="homogeneous fast silo — the deadline solver's easy regime"),
    _scn("bimodal-edge-markov", "bimodal-edge", 500, "markov",
         akw=(("p_off_to_on", 0.35), ("p_on_to_off", 0.12)),
         strategy="stratified",
         note="edge boxes with sticky outages; stratified tier coverage"),
    _scn("uniform-bernoulli-salf", "uniform", 500, "bernoulli",
         akw=(("rate", 0.7),), method="salf",
         note="SALF baseline under iid 70% availability"),
    _scn("bimodal-edge-heterofl", "bimodal-edge", 500, "markov",
         akw=(("p_off_to_on", 0.35), ("p_on_to_off", 0.12)),
         method="heterofl", strategy="stratified",
         note="HeteroFL width scaling on the same sticky-outage edge fleet "
              "as bimodal-edge-markov: slow boxes train narrow submodels"),
    _scn("longtail-mobile-power-of-choice", "longtail-mobile", 600, "diurnal",
         akw=(("mean", 0.6), ("amplitude", 0.35), ("period", 12.0)),
         strategy="power-of-choice",
         note="same population as longtail-mobile-diurnal, capability-biased "
              "cohort selection"),
    _scn("longtail-mobile-diurnal-replan", "longtail-mobile", 300, "diurnal",
         akw=(("mean", 0.42), ("amplitude", 0.5), ("period", 14.0),
              ("phase_spread", 0.5)),
         cohort=48, rounds=14,
         replan=ReplanConfig(trigger="drift", drift_threshold=0.3,
                             steps=300),
         note="one dominant time zone: the reachable count itself swings "
              "274 -> ~0 -> back, night rounds skip entirely; drift-"
              "triggered re-planning re-solves the remaining horizon and "
              "reclaims the stranded deadline budget"),
    _scn("bimodal-edge-markov-replan", "bimodal-edge", 500, "markov",
         akw=(("p_off_to_on", 0.35), ("p_on_to_off", 0.12)),
         strategy="stratified", cohort=32, rounds=14,
         replan=ReplanConfig(trigger="every-k", every=4, steps=300),
         note="same sticky-outage edge fleet as bimodal-edge-markov with "
              "periodic every-k re-solves tracking the un-spent budget and "
              "the Markov-relaxed reachable forecast"),
    _scn("longtail-mobile-diurnal-int8", "longtail-mobile", 600, "diurnal",
         akw=(("mean", 0.6), ("amplitude", 0.35), ("period", 12.0)),
         compression=CompressionConfig(mode="int8"),
         note="same population and seeds as longtail-mobile-diurnal with "
              "int8 client->server payloads: the reduction consumes the "
              "quantized wire format and the solver prices B_u at 1/4 — "
              "the matched-accuracy compression comparison"),
    _scn("longtail-mobile-buffered", "longtail-mobile", 600, "diurnal",
         akw=(("mean", 0.6), ("amplitude", 0.35), ("period", 12.0)),
         exec=ExecSpec(backend="buffered", lam=0.5),
         note="same population and seeds as longtail-mobile-diurnal on the "
              "buffered semi-async backend: layers a straggler misses at "
              "the deadline are carried server-side and folded into later "
              "rounds with staleness weight 0.5**tau"),
    _scn("bimodal-edge-buffered-salf", "bimodal-edge", 500, "markov",
         akw=(("p_off_to_on", 0.35), ("p_on_to_off", 0.12)),
         method="salf", strategy="stratified",
         exec=ExecSpec(backend="buffered", lam=0.6, max_age=3),
         note="fixed-deadline SALF + carry buffer on the sticky-outage "
              "edge fleet: the deadline never adapts, so the buffered "
              "delayed gradients are the only channel recovering the "
              "stragglers' unfinished layers"),
    _scn("lm-uniform-bernoulli", "uniform", 60, "bernoulli",
         akw=(("rate", 0.7),), model="lm", cohort=8, rounds=8, eta0=0.5,
         note="reduced LM arch on synthetic token streams against a churny "
              "fleet — the task-adapter path: same RoundRuntime, LM cohort "
              "source + token-loss eval via repro.fl.tasks"),
    _scn("longtail-mobile-1m-hierarchical", "longtail-mobile", 1_000_000,
         "bernoulli", akw=(("rate", 0.7),),
         population="parametric:longtail-mobile", regions=4,
         exec=ExecSpec(backend="hierarchical", regions=4),
         rounds=6,
         note="one million lazily-drawn devices (parametric population, "
              "O(cohort) per round) aggregated through 4 edge regions: "
              "per-region partials against global counts, one global Eq. 5 "
              "fold — the two-tier topology of planet-scale deployments"),
]}


def get_scenario(name: str) -> Scenario:
    if name not in SCENARIOS:
        raise KeyError(f"unknown scenario {name!r}; known: {sorted(SCENARIOS)}")
    return SCENARIOS[name]


def run_scenario(scn: Scenario, *, rounds: Optional[int] = None,
                 fleet_size: Optional[int] = None,
                 cohort_size: Optional[int] = None,
                 exec: Optional[ExecSpec] = None,
                 backend: Optional[str] = None,
                 population: Optional[PopulationSpec] = None,
                 replan=None, replan_every: Optional[int] = None,
                 compression=None, topk_frac: Optional[float] = None,
                 seed: int = 0,
                 solver_steps: int = 600, eval_every: int = 1,
                 verbose: bool = True, events: Optional[str] = None,
                 tracer=None) -> dict:
    """Run one scenario; returns the History dict (+ fleet/availability
    descriptions) consumable by ``benchmarks/report.py``.

    ``exec`` (:class:`repro.fl.spec.ExecSpec`) overrides the scenario's
    execution spec wholesale; the ``backend`` / ``compression`` /
    ``topk_frac`` kwargs remain as deprecated aliases layered on the
    FleetConfig's resolved spec (:meth:`FleetConfig.exec_spec`) through
    the same :meth:`ExecSpec.resolve` path. ``population``
    (:class:`repro.fleet.population.PopulationSpec` or a source string)
    likewise overrides WHO the scenario runs against wholesale
    (:meth:`FleetConfig.population_spec` is the base). ``replan`` (trigger
    name or ``ReplanConfig``) and ``replan_every`` override the
    FleetConfig's online re-planning block. ``events`` writes the
    structured telemetry stream (phase spans, clock-model ledger, the
    buffered backend's carry columns, the hierarchical backend's region
    census) to a JSONL file for ``python -m repro.obs.timeline``;
    ``tracer`` passes an already-built :class:`repro.obs.Tracer` instead
    (the caller keeps ownership — it is not closed here)."""
    fc = scn.fleet
    if fleet_size is not None:
        fc = dataclasses.replace(fc, size=fleet_size)
    if cohort_size is not None:
        fc = dataclasses.replace(fc, cohort_size=cohort_size)
    if replan is not None:
        rp = (replan if isinstance(replan, ReplanConfig)
              else dataclasses.replace(fc.replan, trigger=replan))
        fc = dataclasses.replace(fc, replan=rp)
    if replan_every is not None:
        fc = dataclasses.replace(
            fc, replan=dataclasses.replace(fc.replan, every=replan_every))
    spec = ExecSpec.resolve(
        exec, base=fc.exec_spec(), backend=backend,
        compression=(make_compression(compression)
                     if compression is not None else None))
    if topk_frac is not None:
        spec = dataclasses.replace(
            spec, compression=dataclasses.replace(spec.compression,
                                                  top_k=float(topk_frac)))
    rounds = scn.rounds if rounds is None else rounds

    pspec = fc.population_spec()
    if population is not None:
        pspec = (population if isinstance(population, PopulationSpec)
                 else PopulationSpec.resolve(base=pspec, source=population))
    # availability seeded with fc.seed + run seed, exactly the legacy
    # make_availability call — bit-identical trajectories through the
    # Population front door
    pop = pspec.build(avail_seed=fc.seed + seed)
    # virtual data sharding (device id mod shards) caps the partition at
    # 1024 shards, so million-device populations never materialize
    # per-device arrays; populations at or below the cap keep the legacy
    # one-shard-per-device layout
    n_shards = min(pop.size, 1024)
    eval_m = None
    if scn.model == "lm":
        # task-adapter path: the same runtime trains a reduced LM arch on
        # token-stream shards with token-loss eval (repro.fl.tasks)
        from repro.configs import get_config
        from repro.fl.tasks import (lm_eval_metrics, lm_fleet_data,
                                    make_lm_model)
        arch_cfg = get_config(scn.arch).reduced()
        model = make_lm_model(arch_cfg)
        data = lm_fleet_data(arch_cfg, n_shards, seq=32,
                             rows_per_device=16, seed=seed)
        eval_m = lm_eval_metrics
    else:
        x_tr, y_tr, x_te, y_te = make_image_dataset(
            "mnist", n_train=scn.n_train, n_test=scn.n_test, seed=seed,
            noise_std=1.0)
        data = partition_fleet(x_tr, y_tr, x_te, y_te, n_shards,
                               alpha=scn.alpha, seed=seed)
        model = make_cnn() if scn.model == "cnn" else make_mlp()

    own_tracer = tracer is None and events is not None
    if own_tracer:
        tracer = obs.make_tracer(events)
    t0 = obs.now()
    try:
        _, hist = run_fleet(
            model, pop, data=data, method=scn.method, rounds=rounds,
            cohort_size=fc.cohort_size, cohort_strategy=fc.cohort_strategy,
            exec=spec, eta0=scn.eta0,
            solver_steps=solver_steps, eval_every=eval_every, seed=seed,
            verbose=verbose, replan=fc.replan, eval_metrics=eval_m,
            tracer=tracer)
    finally:
        if own_tracer:
            tracer.close()
    out = hist.as_dict()
    out["wall_s"] = round(obs.now() - t0, 2)
    if events is not None:
        out["events_path"] = os.path.abspath(events)
    out["scenario"] = scn.name
    desc = pop.describe()
    out["fleet"] = desc["fleet"]
    out["availability"] = desc["availability"]
    out["population"] = pspec.as_dict()
    out["cohort"] = {"size": fc.cohort_size, "strategy": fc.cohort_strategy}
    out["backend"] = spec.backend
    out["replan"] = dataclasses.asdict(fc.replan)
    out["compression"] = dataclasses.asdict(spec.compression)
    out["exec"] = spec.as_dict()
    return out


def save_scenario_result(name: str, method: str, result: dict,
                         path: str = RESULTS_PATH) -> str:
    """Merge one run into experiments/results/fleet_scenarios.json in the
    {setting: {method: history}} layout section_repro expects."""
    os.makedirs(os.path.dirname(path), exist_ok=True)
    payload = {}
    if os.path.exists(path):
        with open(path) as f:
            payload = json.load(f)
    payload.setdefault(name, {})[method] = result
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return os.path.abspath(path)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="Fleet-scenario runner (see module docstring)")
    ap.add_argument("--list", action="store_true", help="list scenarios")
    ap.add_argument("--run", default=None, metavar="NAME")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--cohort", type=int, default=None)
    ap.add_argument("--replan", default=None, choices=list(TRIGGERS),
                    help="online re-planning trigger override "
                         "(repro.core.replan; scenarios carry their own "
                         "default in FleetConfig.replan)")
    ap.add_argument("--replan-every", type=int, default=None,
                    help="every-k re-plan period override")
    # the shared execution-spec flag block (--backend / --compression /
    # --topk-frac / --agg-impl / --lam / ...) — one surface with
    # repro.launch.train, derived from repro.fl.spec.ExecSpec
    ExecSpec.add_cli_args(ap)
    # the shared population flag block (--population / --fleet-size /
    # --availability / --regions) — repro.fleet.population.PopulationSpec
    PopulationSpec.add_cli_args(ap)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--solver-steps", type=int, default=600)
    ap.add_argument("--events", default=None, metavar="PATH",
                    help="write the structured telemetry stream (phase "
                         "spans, clock-model ledger) to this JSONL file; "
                         "render with python -m repro.obs.timeline")
    ap.add_argument("--save", action="store_true",
                    help="merge the History into experiments/results/"
                         "fleet_scenarios.json for benchmarks.report")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    if args.list or not args.run:
        print(f"{'scenario':38s} {'fleet':28s} {'avail':10s} "
              f"{'cohort':22s} {'method':9s} {'backend':9s} replan")
        for s in SCENARIOS.values():
            fc = s.fleet
            print(f"{s.name:38s} {fc.preset + ' x' + str(fc.size):28s} "
                  f"{fc.availability:10s} "
                  f"{str(fc.cohort_size) + ' ' + fc.cohort_strategy:22s} "
                  f"{s.method:9s} {fc.exec_spec().backend:9s} "
                  f"{fc.replan.trigger}")
            if s.note:
                print(f"    {s.note}")
        return

    try:
        scn = get_scenario(args.run)
    except KeyError as e:
        ap.error(str(e.args[0]))
    spec = ExecSpec.from_cli(args, base=scn.fleet.exec_spec())
    pop_flags = (args.population, args.fleet_size, args.availability,
                 args.regions)
    pspec = (PopulationSpec.from_cli(args,
                                     base=scn.fleet.population_spec())
             if any(v is not None for v in pop_flags) else None)
    res = run_scenario(scn, rounds=args.rounds,
                       cohort_size=args.cohort, exec=spec, population=pspec,
                       replan=args.replan, replan_every=args.replan_every,
                       seed=args.seed, solver_steps=args.solver_steps,
                       verbose=not args.quiet, events=args.events)
    acc = res["accuracy"][-1] if res["accuracy"] else float("nan")
    rounds_done = res["rounds"][-1] if res["rounds"] else 0
    print(f"[{scn.name}] method={scn.method} fleet={res['fleet']['size']} "
          f"rounds={rounds_done} final_acc={acc:.4f} "
          f"wall={res['wall_s']:.1f}s")
    print(f"  avail/round: {res['available']}")
    print(f"  deadlines:   {[round(d, 3) for d in res['deadlines']]}")
    if res["replans"]:
        print(f"  replans:     "
              f"{[(r['round'], r['U_est'], round(r['m'], 2)) for r in res['replans']]}")
    if args.events:
        print(f"  events:      {res['events_path']} "
              f"(render: python -m repro.obs.timeline {args.events})")
    if args.save:
        path = save_scenario_result(scn.name, scn.method, res)
        print(f"  saved -> {path}")


if __name__ == "__main__":
    main()
