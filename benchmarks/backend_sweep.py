"""Backend sweep — wall-clock per round for the four execution backends
(dense / chunked / shard_map / temporal) across cohort sizes {16, 64, 256},
plus the compile-time memory effect of params-buffer donation and the
``compression`` section: bytes-on-the-wire per round, s/round, and final
accuracy for none / int8 / topk8 client->server payloads on the reduced
LM arch (``repro.core.compression``; byte counts are analytic and
deterministic, gated exactly by ``benchmarks/run.py --check-against``).

Drives :class:`repro.fl.runtime.RoundRuntime` directly: one warmup pass
compiles each backend's round step, then a timed pass measures steady-state
seconds per round (eval excluded from the loop via a final-round-only
cadence). On a single-device host the shard_map mesh has one shard; set
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before running to
sweep a real N-way client mesh.

The ``donation`` section lowers the dense and temporal round steps with
``donate_argnums`` on and off and reports XLA's compiled memory analysis:
``peak_bytes = argument + output + temp - aliased`` — donated params alias
the updated params in place, so the donated peak drops by ~one parameter
buffer. Emits ``experiments/results/backend_sweep.json`` consumed by
``benchmarks/report.py``.
"""
from __future__ import annotations

import time

from benchmarks.common import cached_result, save_result

COHORTS = (16, 64, 256)
BACKENDS = ("dense", "chunked", "shard_map", "temporal")
DONATION_BACKENDS = ("dense", "temporal")
COMPRESSION_MODES = ("none", "int8", "topk8")


def _sweep_one(U: int, backend: str, *, rounds: int, chunk_size: int,
               n_train: int) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.core.baselines import make_policy
    from repro.core.types import AnalysisConfig
    from repro.data.synthetic import make_image_dataset
    from repro.fl.partition import iid_partition, stack_clients
    from repro.fl.runtime import RoundRuntime, StaticCohortSource, probe_s_max
    from repro.models.paper_models import make_mlp

    x_tr, y_tr, x_te, y_te = make_image_dataset(
        "mnist", n_train=n_train, n_test=256, seed=0, noise_std=1.0)
    parts = iid_partition(len(y_tr), U, seed=0)
    cx, cy, counts = stack_clients(x_tr, y_tr, parts)
    model = make_mlp()
    cfg = AnalysisConfig.default(U=U, L=model.L, R=rounds,
                                 T_max=rounds * model.L * 0.5, eta0=1.0,
                                 seed=0)
    policy = make_policy("salf", cfg)   # fixed deadline, no solver cost
    s_max = max(min(probe_s_max(policy, rounds), int(cy.shape[1])), 2)

    runtime = RoundRuntime(model, policy, backend=backend,
                           chunk_size=chunk_size)
    source = StaticCohortSource(jnp.asarray(cx), jnp.asarray(cy),
                                jnp.asarray(counts))
    common = dict(T_max=cfg.T_max * 10, eta=cfg.eta, s_max=s_max,
                  test_x=jnp.asarray(x_te), test_y=jnp.asarray(y_te),
                  eval_every=rounds + 1)
    # warmup compiles the round step + eval; the jit caches live on the
    # backend / model, so the timed pass measures steady-state rounds
    runtime.run(source, rounds=1, key=jax.random.PRNGKey(1), **common)
    t0 = time.time()
    _, hist = runtime.run(source, rounds=rounds, key=jax.random.PRNGKey(0),
                          **common)
    wall = time.time() - t0
    return {
        "backend": backend,
        "cohort": U,
        "rounds": rounds,
        "U_pad": runtime.backend.cohort_pad(U),
        "wall_s": round(wall, 4),
        "wall_per_round_s": round(wall / rounds, 4),
        "final_acc": hist.accuracy[-1] if hist.accuracy else None,
        "devices": len(jax.devices()),
        **runtime.backend.describe(),
    }


def _compression_one(mode: str, *, rounds: int,
                     arch: str = "qwen1.5-4b") -> dict:
    """Compressed vs dense client->server payloads on the reduced LM arch
    (the federated LM driver, dense backend).

    Byte counters are the backends' analytic per-round payload accounting
    (``repro.core.compression.payload_bytes``) — deterministic given the
    arch and cohort, so the CI gate matches ``bytes_per_round_*`` exactly
    while wall-clock and accuracy keep their usual tolerances.
    """
    from repro import obs
    from repro.launch.train import run_training

    tracer = obs.Tracer(obs.MemorySink())
    t0 = time.time()
    _, hist = run_training(arch, rounds=rounds, tmax=20.0 * rounds, U=4,
                           seq=16, n_seq=24, backend="dense",
                           solver_steps=60,
                           compression=None if mode == "none" else mode,
                           eval_every=rounds, verbose=False, tracer=tracer)
    wall = time.time() - t0
    done = len(hist.rounds) or 1
    ctr = tracer.summary().get("counters", {})
    logical = int(ctr.get("aggregate_bytes_logical", 0))
    wire = int(ctr.get("aggregate_bytes_wire", 0))
    return {
        "mode": mode, "arch": arch, "rounds": done,
        "wall_s": round(wall, 4),
        "wall_per_round_s": round(wall / done, 4),
        "final_acc": hist.accuracy[-1] if hist.accuracy else None,
        "bytes_per_round_logical": logical // done,
        "bytes_per_round_wire": wire // done,
        "wire_ratio": round(logical / wire, 4) if wire else None,
    }


def _donation_memory(*, U: int = 4, s_max: int = 8, seq: int = 32,
                     arch: str = "qwen1.5-4b") -> dict:
    """Compiled-memory comparison of the LM round step with and without
    params donation, per single-jit-per-round backend.

    ``peak_bytes = argument + output + temp - aliased``: with donation the
    params argument aliases the updated-params output in place, so one
    full parameter buffer (``alias_bytes == param_bytes``) comes off the
    peak. On the reduced CPU arch the gradient activations dominate the
    peak, so the ratio is modest; on the parameter-dominated full configs
    the same aliasing removes the dominant term. Returns {} when the
    platform's compiler exposes no memory analysis.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.fl.backends import make_backend
    from repro.fl.tasks import make_lm_model

    cfg = get_config(arch).reduced()
    model = make_lm_model(cfg)
    L = model.L
    params = jax.eval_shape(model.init,
                            jax.ShapeDtypeStruct((2,), np.uint32))
    param_bytes = int(sum(np.prod(leaf.shape) * leaf.dtype.itemsize
                          for leaf in jax.tree_util.tree_leaves(params)))
    sds = jax.ShapeDtypeStruct
    args = (params,
            sds((U, s_max, seq + 1), jnp.int32),       # xb (token rows)
            sds((U, s_max), jnp.int32),                # yb (unused for LM)
            sds((U, s_max), jnp.float32),              # wb
            sds((U, L), jnp.float32),                  # mask
            sds((L,), jnp.float32),                    # p
            sds((), jnp.float32),                      # eta
            None)                                      # wmasks
    out = {}
    for name in DONATION_BACKENDS:
        row = {"arch": cfg.name, "param_bytes": param_bytes}
        for donate in (True, False):
            bk = make_backend(name, model, donate=donate)
            step = bk._step(True, False)
            try:
                ma = step.lower(*args).compile().memory_analysis()
            except Exception as e:                      # pragma: no cover
                row[f"{'donated' if donate else 'undonated'}_error"] = str(e)
                continue
            if ma is None:                              # pragma: no cover
                continue
            peak = (ma.argument_size_in_bytes + ma.output_size_in_bytes
                    + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
            key = "donated" if donate else "undonated"
            row[f"{key}_peak_bytes"] = int(peak)
            row[f"{key}_alias_bytes"] = int(ma.alias_size_in_bytes)
        if ("donated_peak_bytes" in row and "undonated_peak_bytes" in row
                and row["undonated_peak_bytes"] > 0):
            row["peak_ratio"] = round(row["donated_peak_bytes"]
                                      / row["undonated_peak_bytes"], 4)
            out[name] = row
    return out


def run(quick: bool = False) -> dict:
    cached = cached_result("backend_sweep")
    if cached is not None:
        return cached
    cohorts = COHORTS[:2] if quick else COHORTS
    rounds = 3 if quick else 6
    n_train = 1024 if quick else 2048
    result = {}
    for U in cohorts:
        row = {}
        for backend in BACKENDS:
            rec = _sweep_one(U, backend, rounds=rounds,
                             chunk_size=max(U // 4, 8), n_train=n_train)
            row[backend] = rec
            print(f"[backend_sweep] cohort={U:4d} {backend:9s} "
                  f"{rec['wall_per_round_s']:8.3f}s/round "
                  f"(pad {rec['U_pad']}, {rec['devices']} dev)")
        result[f"cohort_{U}"] = row
    comp = {}
    for mode in COMPRESSION_MODES:
        rec = _compression_one(mode, rounds=2 if quick else 4)
        comp[mode] = rec
        ratio = rec["wire_ratio"]
        print(f"[backend_sweep] compression {mode:6s} "
              f"{rec['bytes_per_round_wire']:>12,}B/round wire "
              f"({ratio}x vs dense f32) "
              f"{rec['wall_per_round_s']:8.3f}s/round "
              f"acc={rec['final_acc']:.4f}")
    if comp["int8"]["wire_ratio"] < 3.5:      # acceptance floor
        print(f"[backend_sweep] WARNING: int8 wire ratio "
              f"{comp['int8']['wire_ratio']} < 3.5x")
    result["compression"] = comp
    donation = _donation_memory()
    if donation:
        result["donation"] = donation
        for name, row in donation.items():
            print(f"[backend_sweep] donation {name:9s} peak "
                  f"{row['donated_peak_bytes']:,} vs "
                  f"{row['undonated_peak_bytes']:,} bytes "
                  f"(x{row['peak_ratio']}, aliases "
                  f"{row['donated_alias_bytes']:,} param bytes in place)")
    save_result("backend_sweep", result)
    return result


if __name__ == "__main__":
    run()
