"""Backend sweep — wall-clock per round for the three execution backends
(dense / chunked / shard_map) across cohort sizes {16, 64, 256}.

Drives :class:`repro.fl.runtime.RoundRuntime` directly: one warmup pass
compiles each backend's round step, then a timed pass measures steady-state
seconds per round (eval excluded from the loop via a final-round-only
cadence). On a single-device host the shard_map mesh has one shard; set
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before running to
sweep a real N-way client mesh. Emits ``experiments/results/
backend_sweep.json`` consumed by ``benchmarks/report.py``.
"""
from __future__ import annotations

import time

from benchmarks.common import cached_result, save_result

COHORTS = (16, 64, 256)
BACKENDS = ("dense", "chunked", "shard_map")


def _sweep_one(U: int, backend: str, *, rounds: int, chunk_size: int,
               n_train: int) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.core.baselines import make_policy
    from repro.core.types import AnalysisConfig
    from repro.data.synthetic import make_image_dataset
    from repro.fl.partition import iid_partition, stack_clients
    from repro.fl.runtime import RoundRuntime, StaticCohortSource, probe_s_max
    from repro.models.paper_models import make_mlp

    x_tr, y_tr, x_te, y_te = make_image_dataset(
        "mnist", n_train=n_train, n_test=256, seed=0, noise_std=1.0)
    parts = iid_partition(len(y_tr), U, seed=0)
    cx, cy, counts = stack_clients(x_tr, y_tr, parts)
    model = make_mlp()
    cfg = AnalysisConfig.default(U=U, L=model.L, R=rounds,
                                 T_max=rounds * model.L * 0.5, eta0=1.0,
                                 seed=0)
    policy = make_policy("salf", cfg)   # fixed deadline, no solver cost
    s_max = max(min(probe_s_max(policy, rounds), int(cy.shape[1])), 2)

    runtime = RoundRuntime(model, policy, backend=backend,
                           chunk_size=chunk_size)
    source = StaticCohortSource(jnp.asarray(cx), jnp.asarray(cy),
                                jnp.asarray(counts))
    common = dict(T_max=cfg.T_max * 10, eta=cfg.eta, s_max=s_max,
                  test_x=jnp.asarray(x_te), test_y=jnp.asarray(y_te),
                  eval_every=rounds + 1)
    # warmup compiles the round step + eval; the jit caches live on the
    # backend / model, so the timed pass measures steady-state rounds
    runtime.run(source, rounds=1, key=jax.random.PRNGKey(1), **common)
    t0 = time.time()
    _, hist = runtime.run(source, rounds=rounds, key=jax.random.PRNGKey(0),
                          **common)
    wall = time.time() - t0
    return {
        "backend": backend,
        "cohort": U,
        "rounds": rounds,
        "U_pad": runtime.backend.cohort_pad(U),
        "wall_s": round(wall, 4),
        "wall_per_round_s": round(wall / rounds, 4),
        "final_acc": hist.accuracy[-1] if hist.accuracy else None,
        "devices": len(jax.devices()),
        **runtime.backend.describe(),
    }


def run(quick: bool = False) -> dict:
    cached = cached_result("backend_sweep")
    if cached is not None:
        return cached
    cohorts = COHORTS[:2] if quick else COHORTS
    rounds = 3 if quick else 6
    n_train = 1024 if quick else 2048
    result = {}
    for U in cohorts:
        row = {}
        for backend in BACKENDS:
            rec = _sweep_one(U, backend, rounds=rounds,
                             chunk_size=max(U // 4, 8), n_train=n_train)
            row[backend] = rec
            print(f"[backend_sweep] cohort={U:4d} {backend:9s} "
                  f"{rec['wall_per_round_s']:8.3f}s/round "
                  f"(pad {rec['U_pad']}, {rec['devices']} dev)")
        result[f"cohort_{U}"] = row
    save_result("backend_sweep", result)
    return result


if __name__ == "__main__":
    run()
