"""Roofline analysis: read the dry-run records (experiments/dryrun*/) and
emit the per-(arch x shape x mesh) three-term roofline table, dominant
bottleneck, MODEL_FLOPS = 6 N D (dense) / 6 N_active D (MoE), and the
useful-compute ratio MODEL_FLOPS / HLO_FLOPS.

HLO terms from ``compiled.cost_analysis()`` are PER-DEVICE after SPMD
partitioning, so each term is directly a per-chip seconds estimate:

    compute_s    = flops_per_device / 197e12      (bf16 peak)
    memory_s     = bytes_per_device / 819e9       (HBM)
    collective_s = coll_bytes_per_device / 50e9   (ICI per-link)
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs import ARCHS

DRYRUN_DIRS = ["experiments/dryrun", "experiments/dryrun_multipod"]


def model_flops(arch: str, shape_name: str, meta: dict, chips: int) -> float:
    """Global useful model FLOPs for the lowered step."""
    cfg = ARCHS[arch.removesuffix("-swa4096")] if arch not in ARCHS else ARCHS[arch]
    n = cfg.active_param_count() - (cfg.padded_vocab * cfg.d_model *
                                    (1 if cfg.tie_embeddings else 2))
    if meta.get("step") == "train_step":
        tokens = meta["U"] * meta["client_batch"] * meta["seq"]
        return 6.0 * n * tokens
    if meta.get("step") == "prefill_step":
        tokens = meta["B"] * meta["seq"]
        return 2.0 * n * tokens
    # decode: one token per sequence + attention over the cache
    tokens = meta["B"]
    return 2.0 * n * tokens


def load_records() -> list[dict]:
    recs = []
    for d in DRYRUN_DIRS:
        for fn in sorted(glob.glob(os.path.join(d, "*.json"))):
            with open(fn) as f:
                recs.append(json.load(f))
    return recs


def table(recs: list[dict] | None = None) -> list[dict]:
    recs = recs if recs is not None else load_records()
    rows = []
    for r in recs:
        if "error" in r:
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": r.get("mesh", "?"), "error": r["error"]})
            continue
        roof = r["roofline"]
        mf = model_flops(r["arch"], r["shape"], r, r["chips"])
        hlo_global = r["flops_per_device"] * r["chips"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "step": r.get("step", "?"),
            "compute_s": roof["compute_s"], "memory_s": roof["memory_s"],
            "collective_s": roof["collective_s"],
            "dominant": roof["dominant"],
            "model_flops": mf,
            "useful_ratio": mf / hlo_global if hlo_global else 0.0,
            "step_s_bound": max(roof["compute_s"], roof["memory_s"],
                                roof["collective_s"]),
        })
    return rows


def run(quick: bool = False) -> dict:
    rows = table()
    if not rows:
        print("[roofline] no dry-run records found — run "
              "`python -m repro.launch.dryrun --all --out experiments/dryrun`")
        return {"rows": []}
    hdr = (f"{'arch':<22s} {'shape':<12s} {'mesh':<8s} {'compute_s':>10s} "
           f"{'memory_s':>10s} {'collect_s':>10s} {'dominant':>12s} "
           f"{'useful%':>8s}")
    print(hdr)
    print("-" * len(hdr))
    for row in sorted(rows, key=lambda r: (r["mesh"], r["arch"], r["shape"])):
        if "error" in row:
            print(f"{row['arch']:<22s} {row['shape']:<12s} "
                  f"{row['mesh']:<8s} SKIP/FAIL: {row['error'][:60]}")
            continue
        print(f"{row['arch']:<22s} {row['shape']:<12s} {row['mesh']:<8s} "
              f"{row['compute_s']:>10.3e} {row['memory_s']:>10.3e} "
              f"{row['collective_s']:>10.3e} {row['dominant']:>12s} "
              f"{100 * row['useful_ratio']:>7.1f}%")
    return {"rows": rows}


if __name__ == "__main__":
    run()
