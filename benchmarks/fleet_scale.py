"""Fleet-scale — per-round wall time vs population size at a FIXED cohort.

The Population API's acceptance bar: simulation cost must be O(cohort),
not O(fleet). A lazy :class:`~repro.fleet.population.ParametricPopulation`
(longtail-mobile, bernoulli churn, 4 edge regions, hierarchical two-tier
aggregation) is swept from 10k to 1M devices with the cohort pinned, and
the per-round wall time is expected to stay ~flat — ``flat_ratio``
(1M-per-round over 10k-per-round) should sit near 1.0 and must not exceed
1.5x. The prefetch pipeline's AOT warm-up (``backend.warm_up`` via
``ExecSpec.pipeline="prefetch"``) absorbs jit compilation inside each
sweep — its one-off cost lands in the ``warm_up_s`` counter and is
subtracted from the timed wall, so the ratio compares steady-state
rounds, not compile cost.
"""
from __future__ import annotations

from benchmarks.common import cached_result, events_path, save_result

SIZES = (10_000, 100_000, 1_000_000)
COHORT = 16
FLAT_BOUND = 1.5


def run(quick: bool = False) -> dict:
    cached = cached_result("fleet_scale")
    if cached is not None:
        return cached
    from repro import obs
    from repro.data.synthetic import make_image_dataset
    from repro.fl.spec import ExecSpec
    from repro.fleet.engine import partition_fleet, run_fleet
    from repro.fleet.population import make_population
    from repro.models.paper_models import make_mlp

    rounds = 3 if quick else 5
    x_tr, y_tr, x_te, y_te = make_image_dataset(
        "mnist", n_train=800 if quick else 1600, n_test=300, seed=0,
        noise_std=1.0)
    # 64 virtual shards; device ids index them modulo, so the SAME data
    # serves every population size (only WHO trains varies with size)
    data = partition_fleet(x_tr, y_tr, x_te, y_te, 64, alpha=0.5, seed=0)

    def population(size: int):
        return make_population(
            "parametric:longtail-mobile", size=size,
            availability="bernoulli", availability_kwargs=(("rate", 0.7),),
            regions=4)

    def sweep(size: int, *, rounds: int, tracer=None):
        return run_fleet(make_mlp(), population(size), data=data,
                         method="adel", rounds=rounds, cohort_size=COHORT,
                         solver_steps=300, eval_every=max(rounds // 2, 1),
                         seed=0, verbose=False,
                         exec=ExecSpec(backend="hierarchical", regions=4,
                                       pipeline="prefetch"),
                         tracer=tracer)

    result = {}
    for size in SIZES:
        tracer = obs.make_tracer(events_path(f"fleet_scale.{size}"))
        t0 = obs.now()
        _, hist = sweep(size, rounds=rounds, tracer=tracer)
        wall = obs.now() - t0
        tracer.close()
        # the AOT warm-up compiles (and the prefetcher then hides the
        # planning of) the round step; its one-off cost is not a per-round
        # cost, so it is reported separately and excluded from the rate
        counters = (hist.telemetry or {}).get("counters", {})
        warm = float(counters.get("warm_up_s", 0.0))
        wall = max(wall - warm, 0.0)
        row = {"fleet_size": size, "rounds": rounds, "cohort": COHORT,
               "wall_s": round(wall, 3),
               "warm_up_s": round(warm, 3),
               "wall_per_round_s": round(wall / rounds, 4),
               "final_acc": round(float(hist.accuracy[-1]), 4)
               if hist.accuracy else 0.0,
               "available_last": int(hist.available[-1])
               if hist.available else 0}
        print(f"[fleet_scale] fleet={size:>9,d} cohort={COHORT} "
              f"rounds={rounds} wall/round={row['wall_per_round_s']:.3f}s "
              f"final_acc={row['final_acc']:.4f}")
        result[f"fleet_{size}"] = row

    lo = result[f"fleet_{SIZES[0]}"]["wall_per_round_s"]
    hi = result[f"fleet_{SIZES[-1]}"]["wall_per_round_s"]
    result["flat_ratio"] = round(hi / max(lo, 1e-9), 3)
    verdict = "OK" if result["flat_ratio"] <= FLAT_BOUND else "VIOLATION"
    print(f"[fleet_scale] per-round {SIZES[0]:,d}->{SIZES[-1]:,d}: "
          f"x{result['flat_ratio']} (bound {FLAT_BOUND}x) {verdict}")
    save_result("fleet_scale", result)
    return result


if __name__ == "__main__":
    run()
