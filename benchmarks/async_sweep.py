"""Async sweep — round-synchronous aggregation vs the buffered semi-async
backend's staleness-weighted delayed gradients, under the same ``T_max``.

Three arms per fleet scenario, all sharing the engine's deadline budget
(``T_max = rounds * L * 0.5`` — identical across arms because the model
and round count match):

* ``adel-sync``     — ADEL's adaptive deadlines, round-synchronous
                      aggregation (the scenario's default backend): work
                      past the deadline is simply lost,
* ``salf-buffered`` — SALF's fixed deadline + the buffered backend: the
                      deadline never adapts, so the carry buffer is the
                      only channel recovering stragglers' unfinished
                      layers (folded later with weight ``lam**tau``),
* ``adel-buffered`` — both: adaptive deadlines AND the carry buffer.

Emits ``experiments/results/async_sweep.json`` in the
``{scenario: {arm: history}}`` layout plus one telemetry event stream per
arm (``events/async_sweep.<scenario>.<arm>.jsonl`` — the clock-model
ledger grows the ``carried_in/carried_out/stale`` columns); rendered by
``benchmarks/report.py`` (staleness section) and gated in CI by
``benchmarks.run --check-against``.
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import cached_result, events_path, save_result
from repro.fl.spec import ExecSpec

SCENARIO_NAMES = ("longtail-mobile-diurnal", "bimodal-edge-markov")

# staleness decay of the delayed-gradient fold, w(tau) = LAM ** tau
LAM = 0.5


def _arms() -> tuple:
    buffered = ExecSpec(backend="buffered", lam=LAM)
    return (("adel-sync", "adel", None),
            ("salf-buffered", "salf", buffered),
            ("adel-buffered", "adel", buffered))


def run(quick: bool = False) -> dict:
    cached = cached_result("async_sweep")
    if cached is not None:
        return cached
    from repro.fleet.scenarios import get_scenario, run_scenario

    fleet_size = 200 if quick else 400
    rounds = 5 if quick else 10
    result = {}
    for name in SCENARIO_NAMES:
        base = get_scenario(name)
        base = dataclasses.replace(base, n_train=1200 if quick else 2500,
                                   n_test=400)
        print(f"[async_sweep] {name}: fleet={fleet_size} rounds={rounds} "
              f"lam={LAM}")
        row = {}
        for arm, method, spec in _arms():
            scn = dataclasses.replace(base, method=method)
            hist = run_scenario(
                scn, rounds=rounds, fleet_size=fleet_size, exec=spec,
                solver_steps=400, eval_every=2, verbose=False,
                events=events_path(f"async_sweep.{name}.{arm}"))
            acc = hist["accuracy"][-1] if hist["accuracy"] else 0.0
            drift = (hist.get("telemetry") or {}).get("drift", {})
            carried = drift.get("carried_in_total")
            extra = (f" carried_in={carried} "
                     f"stale_mean={drift.get('stale_mean', '—')}"
                     if carried is not None else "")
            print(f"  [{arm:13s}] rounds="
                  f"{hist['rounds'][-1] if hist['rounds'] else 0}"
                  f"  final_acc={acc:.4f}  wall={hist['wall_s']:.1f}s"
                  f"{extra}")
            row[arm] = hist
        result[name] = row
    save_result("async_sweep", result)
    return result


if __name__ == "__main__":
    run()
