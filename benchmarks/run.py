"""Benchmark orchestrator — one entry per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig2_mnist]

Prints a ``name,wall_s,derived`` CSV summary at the end.
"""
from __future__ import annotations

import argparse
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced rounds/data for a fast pass")
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)

    from benchmarks import (backend_sweep, fig2_mnist, fig3_cifar,
                            fig4_robustness, fleet_smoke, roofline,
                            table2_budgets)
    suites = {
        "fig2_mnist": fig2_mnist.run,
        "fig3_cifar": fig3_cifar.run,
        "fig4_robustness": fig4_robustness.run,
        "table2_budgets": table2_budgets.run,
        "roofline": roofline.run,
        "fleet_smoke": fleet_smoke.run,
        "backend_sweep": backend_sweep.run,
    }
    if args.only:
        suites = {args.only: suites[args.only]}

    rows = []
    for name, fn in suites.items():
        print(f"\n===== {name} =====")
        t0 = time.time()
        result = fn(quick=args.quick)
        wall = time.time() - t0
        derived = _derive(name, result)
        rows.append((name, wall, derived))

    print("\nname,wall_s,derived")
    for name, wall, derived in rows:
        print(f"{name},{wall:.1f},{derived}")


def _derive(name: str, result: dict) -> str:
    try:
        if name == "roofline":
            rows = result["rows"]
            ok = [r for r in rows if "error" not in r]
            return f"{len(ok)}/{len(rows)} combos"
        if name == "backend_sweep":
            pieces = []
            for setting, row in sorted(
                    result.items(),
                    key=lambda kv: int(kv[0].split("_")[-1])):
                walls = "/".join(f"{row[b]['wall_per_round_s']:.2f}"
                                 for b in ("dense", "chunked", "shard_map")
                                 if b in row)
                pieces.append(f"{setting.removeprefix('cohort_')}:{walls}s")
            return "dense/chunked/shard " + " ".join(pieces)
        if name == "table2_budgets":
            accs = []
            for k, v in result.items():
                if k.startswith("budget_") and "adel" in v:
                    accs.append(f"{k.split('_')[1]}:"
                                f"{v['adel']['final_acc']:.3f}")
            return "adel " + " ".join(accs)
        # figures: adel vs best baseline final accuracy
        def final_acc(d):
            if not isinstance(d, dict):
                return None
            if d.get("accuracy"):
                return d["accuracy"][-1]
            return d.get("final_acc")

        pieces = []
        for arch, methods in result.items():
            if not isinstance(methods, dict) or "adel" not in methods:
                continue
            a = final_acc(methods["adel"])
            bases = [final_acc(v) for k, v in methods.items() if k != "adel"]
            bases = [b for b in bases if b is not None]
            base = max(bases) if bases else float("nan")
            pieces.append(f"{arch}:adel={a:.3f}/best_base={base:.3f}")
        return " ".join(pieces)
    except Exception as e:  # pragma: no cover
        return f"derive_error:{e}"


if __name__ == "__main__":
    main()
