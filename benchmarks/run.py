"""Benchmark orchestrator — one entry per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig2_mnist]
    PYTHONPATH=src python -m benchmarks.run --quick \
        --only fleet_smoke,backend_sweep,replan_sweep \
        --check-against experiments/results

Prints a ``name,wall_s,derived`` CSV summary at the end.

``--check-against DIR`` is the CI benchmark-regression gate: every selected
suite is recomputed (the results cache is bypassed) and compared against
the committed baseline JSON in ``DIR``. Wall-clock fields may grow by at
most ``--time-tolerance`` (default 2.5x — shared runners are slow and
noisy), accuracy fields must stay within ``--acc-tolerance`` (default
0.035 absolute — runs are seeded, so only platform float drift remains);
analytic payload-byte fields (``bytes_per_round_*``) are deterministic and
must match exactly; any regression fails the run with a non-zero exit
code. Metrics whose shape changed (e.g. a quick pass checked against a
full baseline) are reported as skipped, not failed.
"""
from __future__ import annotations

import argparse
import json
import os
import time

SUITE_NAMES = ("fig2_mnist", "fig3_cifar", "fig4_robustness",
               "table2_budgets", "roofline", "fleet_smoke", "fleet_scale",
               "backend_sweep", "replan_sweep", "async_sweep", "lm_smoke",
               "pipeline_sweep")

# metric-field classification for the regression gate
_TIME_KEYS = ("wall_s", "wall_per_round_s")
_ACC_KEYS = ("final_acc",)
# analytic payload byte counts (repro.core.compression.payload_bytes) are
# deterministic given arch + cohort — gated by EXACT equality, no tolerance
_BYTES_KEYS = ("bytes_per_round_logical", "bytes_per_round_wire")


def _suites() -> dict:
    from benchmarks import (async_sweep, backend_sweep, fig2_mnist,
                            fig3_cifar, fig4_robustness, fleet_scale,
                            fleet_smoke, lm_smoke, pipeline_sweep,
                            replan_sweep, roofline, table2_budgets)
    return {
        "fig2_mnist": fig2_mnist.run,
        "fig3_cifar": fig3_cifar.run,
        "fig4_robustness": fig4_robustness.run,
        "table2_budgets": table2_budgets.run,
        "roofline": roofline.run,
        "fleet_smoke": fleet_smoke.run,
        "fleet_scale": fleet_scale.run,
        "backend_sweep": backend_sweep.run,
        "replan_sweep": replan_sweep.run,
        "async_sweep": async_sweep.run,
        "lm_smoke": lm_smoke.run,
        "pipeline_sweep": pipeline_sweep.run,
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced rounds/data for a fast pass")
    ap.add_argument("--only", default=None,
                    help="comma-separated suite subset "
                         f"(known: {', '.join(SUITE_NAMES)})")
    ap.add_argument("--check-against", default=None, metavar="DIR",
                    help="benchmark-regression gate: recompute the selected "
                         "suites and fail on regression vs the baseline "
                         "JSONs in DIR")
    ap.add_argument("--time-tolerance", type=float, default=2.5,
                    help="max fresh/baseline wall-clock ratio (gate)")
    ap.add_argument("--time-slack", type=float, default=0.5,
                    help="absolute wall-clock slack in seconds added on "
                         "top of the ratio, so sub-second baselines don't "
                         "flake on scheduler hiccups (gate)")
    ap.add_argument("--acc-tolerance", type=float, default=0.035,
                    help="max |fresh - baseline| accuracy drift (gate)")
    args = ap.parse_args(argv)

    if args.check_against:
        # the gate must measure fresh numbers, never replay the cache —
        # and must never overwrite the baselines it compares against
        # (otherwise a failing local run replaces the baseline and the
        # retry "passes" against its own regression)
        os.environ["REPRO_BENCH_FORCE"] = "1"
        fresh_dir = os.path.join(args.check_against, "fresh")
        os.environ["REPRO_BENCH_OUT"] = fresh_dir
        print(f"[gate] fresh results -> {fresh_dir} "
              f"(baselines in {args.check_against} untouched)")

    suites = _suites()
    assert set(suites) == set(SUITE_NAMES), \
        "SUITE_NAMES out of sync with _suites()"
    if args.only:
        picked = [n.strip() for n in args.only.split(",") if n.strip()]
        unknown = [n for n in picked if n not in suites]
        if unknown:
            ap.error(f"unknown suite(s) {unknown}; "
                     f"known: {', '.join(SUITE_NAMES)}")
        suites = {name: suites[name] for name in picked}

    rows, violations, skipped = [], [], []
    for name, fn in suites.items():
        print(f"\n===== {name} =====")
        baseline = None
        if args.check_against:
            baseline = _load_baseline(args.check_against, name)
            if baseline is None:
                skipped.append(f"{name}: no baseline in "
                               f"{args.check_against} (suite not gated)")
        t0 = time.time()
        result = fn(quick=args.quick)
        wall = time.time() - t0
        derived = _derive(name, result)
        rows.append((name, wall, derived))
        if baseline is not None:
            v, s = check_result(name, result, baseline,
                                time_tol=args.time_tolerance,
                                time_slack=args.time_slack,
                                acc_tol=args.acc_tolerance)
            violations += v
            skipped += s

    print("\nname,wall_s,derived")
    for name, wall, derived in rows:
        print(f"{name},{wall:.1f},{derived}")

    if args.check_against:
        _gate_report(violations, skipped)


def _load_baseline(dirname: str, name: str) -> dict | None:
    path = os.path.join(dirname, f"{name}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def _gate_report(violations: list, skipped: list) -> None:
    print("\n===== benchmark-regression gate =====")
    for s in skipped:
        print(f"  [skip] {s}")
    if violations:
        for v in violations:
            print(f"  [FAIL] {v}")
        raise SystemExit(
            f"benchmark-regression gate: {len(violations)} regression(s)")
    print("  gate PASSED (no regressions vs baseline)")


def _iter_pairs(base, fresh, path=()):
    """Yield (path, baseline_value, fresh_value|None) for every baseline
    leaf; fresh_value is None when the fresh result lacks the path."""
    if isinstance(base, dict):
        for k, v in base.items():
            sub = fresh.get(k) if isinstance(fresh, dict) else None
            yield from _iter_pairs(v, sub, path + (str(k),))
    else:
        yield path, base, fresh


def check_result(name: str, fresh: dict, baseline: dict, *,
                 time_tol: float, acc_tol: float,
                 time_slack: float = 0.5) -> tuple[list, list]:
    """Compare one suite's fresh result against its committed baseline.

    Returns ``(violations, skipped)`` message lists. Wall-clock leaves may
    regress by at most ``time_tol`` x plus ``time_slack`` seconds absolute
    (getting faster is never flagged, and a millisecond-scale baseline
    can't flake the gate on one scheduler hiccup); accuracy leaves must
    stay within ``acc_tol`` absolute. ``accuracy`` trajectory lists are
    compared by final value, and only when the baseline and fresh
    trajectories have the same length (a --quick run checked against a
    full baseline legitimately differs in shape).
    """
    viol, skip = [], []
    for path, bval, fval in _iter_pairs(baseline, fresh):
        key = path[-1]
        where = f"{name}:{'/'.join(path)}"
        if key in _TIME_KEYS and isinstance(bval, (int, float)):
            if not isinstance(fval, (int, float)):
                skip.append(f"{where}: missing in fresh result")
            elif bval > 0 and fval > bval * time_tol + time_slack:
                viol.append(f"{where}: {fval:.3f}s vs baseline "
                            f"{bval:.3f}s (> {time_tol:.1f}x + "
                            f"{time_slack:.1f}s)")
        elif key in _BYTES_KEYS and isinstance(bval, (int, float)):
            if not isinstance(fval, (int, float)):
                skip.append(f"{where}: missing in fresh result")
            elif fval != bval:
                viol.append(f"{where}: {fval} vs baseline {bval} "
                            f"(analytic payload bytes are deterministic — "
                            f"exact match required)")
        elif key in _ACC_KEYS and isinstance(bval, (int, float)):
            if not isinstance(fval, (int, float)):
                skip.append(f"{where}: missing in fresh result")
            elif abs(fval - bval) > acc_tol:
                viol.append(f"{where}: {fval:.4f} vs baseline {bval:.4f} "
                            f"(|diff| > {acc_tol})")
        elif key == "accuracy" and isinstance(bval, list) and bval:
            if not (isinstance(fval, list) and fval):
                skip.append(f"{where}: missing in fresh result")
            elif len(fval) != len(bval):
                skip.append(f"{where}: shape {len(fval)} vs baseline "
                            f"{len(bval)} (quick/full mismatch?)")
            elif abs(fval[-1] - bval[-1]) > acc_tol:
                viol.append(f"{where}[-1]: {fval[-1]:.4f} vs baseline "
                            f"{bval[-1]:.4f} (|diff| > {acc_tol})")
    return viol, skip


def _derive(name: str, result: dict) -> str:
    try:
        if name == "roofline":
            rows = result["rows"]
            ok = [r for r in rows if "error" not in r]
            return f"{len(ok)}/{len(rows)} combos"
        if name == "backend_sweep":
            pieces = []
            cohort_rows = {k: v for k, v in result.items()
                           if k.startswith("cohort_")}
            for setting, row in sorted(
                    cohort_rows.items(),
                    key=lambda kv: int(kv[0].split("_")[-1])):
                walls = "/".join(f"{row[b]['wall_per_round_s']:.2f}"
                                 for b in ("dense", "chunked", "shard_map",
                                           "temporal")
                                 if b in row)
                pieces.append(f"{setting.removeprefix('cohort_')}:{walls}s")
            out = "dense/chunked/shard/temporal " + " ".join(pieces)
            comp = result.get("compression", {})
            ratios = [f"{m}:x{v['wire_ratio']}" for m, v in comp.items()
                      if isinstance(v, dict) and v.get("wire_ratio")
                      and m != "none"]
            if ratios:
                out += " wire " + " ".join(ratios)
            don = result.get("donation", {})
            ratios = [f"{k}:x{v['peak_ratio']}" for k, v in don.items()
                      if isinstance(v, dict) and "peak_ratio" in v]
            if ratios:
                out += " donate_peak " + " ".join(ratios)
            return out
        if name == "lm_smoke":
            pieces = []
            for backend, row in sorted(result.items()):
                if isinstance(row, dict) and "final_loss" in row:
                    pieces.append(f"{backend}:{row['final_loss']:.3f}")
            return "token loss " + " ".join(pieces)
        if name == "pipeline_sweep":
            pieces = []
            for cfg in ("lm", "fleet"):
                row = result.get(cfg)
                if not isinstance(row, dict) or "prefetch" not in row:
                    continue
                pieces.append(
                    f"{cfg}:{row['serial']['wall_per_round_s']:.2f}->"
                    f"{row['prefetch']['wall_per_round_s']:.2f}s/round"
                    f"(+{row.get('speedup_pct', 0):.0f}%,"
                    f"ovl {100 * row['prefetch']['overlap_frac']:.0f}%)")
            return "serial->prefetch " + " ".join(pieces)
        if name == "replan_sweep":
            pieces = []
            for scn, row in result.items():
                accs = "/".join(
                    f"{row[t]['accuracy'][-1]:.3f}"
                    for t in ("never", "every-k", "drift") if t in row)
                pieces.append(f"{scn.split('-')[0]}:{accs}")
            return "never/every-k/drift " + " ".join(pieces)
        if name == "fleet_scale":
            rows = sorted(((v["fleet_size"], v) for k, v in result.items()
                           if isinstance(v, dict) and "fleet_size" in v))
            walls = " ".join(f"{n // 1000}k:{v['wall_per_round_s']:.2f}s"
                             for n, v in rows)
            return (f"per-round {walls} "
                    f"flat x{result.get('flat_ratio', '?')}")
        if name == "async_sweep":
            pieces = []
            for scn, row in result.items():
                accs = "/".join(
                    f"{row[a]['accuracy'][-1]:.3f}"
                    for a in ("adel-sync", "salf-buffered", "adel-buffered")
                    if a in row and row[a].get("accuracy"))
                carried = sum(
                    (row[a].get("telemetry") or {}).get("drift", {})
                    .get("carried_in_total", 0) for a in row)
                pieces.append(f"{scn.split('-')[0]}:{accs} "
                              f"carried:{carried}")
            return "sync/salf-buf/adel-buf " + " ".join(pieces)
        if name == "table2_budgets":
            accs = []
            for k, v in result.items():
                if k.startswith("budget_") and "adel" in v:
                    accs.append(f"{k.split('_')[1]}:"
                                f"{v['adel']['final_acc']:.3f}")
            return "adel " + " ".join(accs)
        # figures: adel vs best baseline final accuracy
        def final_acc(d):
            if not isinstance(d, dict):
                return None
            if d.get("accuracy"):
                return d["accuracy"][-1]
            return d.get("final_acc")

        pieces = []
        for arch, methods in result.items():
            if not isinstance(methods, dict) or "adel" not in methods:
                continue
            a = final_acc(methods["adel"])
            bases = [final_acc(v) for k, v in methods.items() if k != "adel"]
            bases = [b for b in bases if b is not None]
            base = max(bases) if bases else float("nan")
            pieces.append(f"{arch}:adel={a:.3f}/best_base={base:.3f}")
        return " ".join(pieces)
    except Exception as e:  # pragma: no cover
        return f"derive_error:{e}"


if __name__ == "__main__":
    main()
