"""LM smoke — the reduced-arch federated LM driver end-to-end on the
unified round runtime, timed per backend.

Covers the LM path in the CI benchmark-regression gate: ``run_training``
(Problem-2 schedule -> straggler draws -> Eq. 5 aggregation on synthetic
token streams) runs on the ``dense`` and ``temporal`` (grad-accumulation)
backends with donated params buffers; the gate tracks wall-clock and the
final next-token accuracy. Emits ``experiments/results/lm_smoke.json``.
"""
from __future__ import annotations

from benchmarks.common import cached_result, events_path, save_result
from repro.obs import make_tracer, now

ARCH = "qwen1.5-4b"
BACKENDS = ("dense", "temporal")


def run(quick: bool = False) -> dict:
    cached = cached_result("lm_smoke")
    if cached is not None:
        return cached
    from repro.launch.train import run_training

    rounds = 6 if quick else 12
    tmax = 5.0 * rounds
    result = {}
    for backend in BACKENDS:
        tracer = make_tracer(events_path(f"lm_smoke.{backend}"))
        t0 = now()
        _, hist = run_training(ARCH, method="adel", rounds=rounds, tmax=tmax,
                               U=4, seq=32, eta0=1.0, seed=0,
                               backend=backend, solver_steps=600,
                               eval_every=1, verbose=False, tracer=tracer)
        wall = now() - t0
        tracer.close()
        rec = {
            "arch": ARCH,
            "backend": backend,
            "rounds": hist.rounds[-1] if hist.rounds else 0,
            "wall_s": round(wall, 4),
            "wall_per_round_s": round(
                wall / max(hist.rounds[-1] if hist.rounds else 1, 1), 4),
            "final_acc": hist.accuracy[-1] if hist.accuracy else None,
            "final_loss": hist.train_loss[-1] if hist.train_loss else None,
            "loss": [round(x, 6) for x in hist.train_loss],
        }
        result[backend] = rec
        loss = ("-" if rec["final_loss"] is None
                else f"{rec['final_loss']:.4f}")
        acc = "-" if rec["final_acc"] is None else f"{rec['final_acc']:.4f}"
        print(f"[lm_smoke] {backend:9s} rounds={rec['rounds']} "
              f"loss={loss} acc={acc} wall={wall:.1f}s")
    save_result("lm_smoke", result)
    return result


if __name__ == "__main__":
    run()
