"""Fig. 4 — robustness to violated assumptions (CIFAR-like VGG11):
(a) l2 regularization, (b) constant learning rate, (c) E=3, (d) E=5."""
from __future__ import annotations

import numpy as np

from benchmarks.common import (cached_result, run_methods, save_result,
                               setup_fl)
from repro.models.paper_models import make_vgg

METHODS = ["adel", "salf", "drop", "wait"]


def run(quick: bool = False) -> dict:
    cached = cached_result("fig4_robustness")
    if cached is not None:
        return cached
    R = 30 if quick else 60
    U = 8 if quick else 10
    model = make_vgg(11, width_scale=0.125)
    cfg, data = setup_fl("cifar", model, U=U, R=R, T_max=R * model.L * 0.85,
                         alpha=0.5, eta0=0.05, eta_decay=0.02,
                         n_train=800 if quick else 1000,
                         n_test=300 if quick else 400)
    variants = {
        "l2_reg": dict(l2=1e-4),
        "const_lr": dict(eta=np.full(R, 0.04, np.float32)),
        "E3": dict(local_iters=3),
        "E5": dict(local_iters=5),
    }
    if quick:
        variants = {k: variants[k] for k in ["const_lr", "E3"]}
    result = {}
    for name, kw in variants.items():
        print(f"[fig4] variant {name}")
        result[name] = run_methods(model, cfg, data, METHODS,
                                   eval_every=10, **kw)
    save_result("fig4_robustness", result)
    return result


if __name__ == "__main__":
    run()
