"""Fig. 3 — CIFAR-like VGG11/VGG13, Dirichlet(0.5) non-IID: deadline
allocation + convergence. Widths reduced for the CPU container
(DESIGN.md §6); avg depth ~85% of the model per round (paper §IV-B)."""
from __future__ import annotations

from benchmarks.common import (cached_result, run_methods, save_result,
                               setup_fl)
from repro.models.paper_models import make_vgg

METHODS = ["adel", "salf", "drop", "wait"]


def run(quick: bool = False) -> dict:
    cached = cached_result("fig3_cifar")
    if cached is not None:
        return cached
    # CPU-budget adaptation (EXPERIMENTS.md §Repro): width 0.125, ~100
    # rounds with the slow inverse decay eta_t = 0.05/(1+0.02 t) — plain
    # eta0/(1+t) cannot train an 11-layer conv net in <=30 rounds at any
    # stable eta0 (the paper's A30 runs use far more rounds).
    R = 40 if quick else 90
    U = 8 if quick else 10
    result = {}
    depths = [11] if quick else [11, 13]
    for depth in depths:
        model = make_vgg(depth, width_scale=0.125)
        # calibrate so T/m ~ 0.85 L (clients nearly complete a pass)
        cfg, data = setup_fl("cifar", model, U=U, R=R,
                             T_max=R * model.L * 0.85, alpha=0.5,
                             eta0=0.05, eta_decay=0.02,
                             n_train=800 if quick else 1200,
                             n_test=300 if quick else 400)
        print(f"[fig3] vgg{depth}: U={U} R={R} T_max={cfg.T_max}")
        result[f"vgg{depth}"] = run_methods(model, cfg, data, METHODS,
                                            eval_every=10)
    save_result("fig3_cifar", result)
    return result


if __name__ == "__main__":
    run()
