"""Render EXPERIMENTS.md tables from experiments/*.json records.

    PYTHONPATH=src python -m benchmarks.report [--section dryrun|roofline|repro]

Prints GitHub-flavored markdown; EXPERIMENTS.md embeds the output.
"""
from __future__ import annotations

import argparse
import glob
import json
import os


RESULTS = os.path.join(os.path.dirname(__file__), "..", "experiments")


def _load_dryrun():
    recs = []
    for d in ("dryrun", "dryrun_multipod"):
        for fn in sorted(glob.glob(os.path.join(RESULTS, d, "*.json"))):
            with open(fn) as f:
                recs.append(json.load(f))
    return recs


def _fmt_bytes(b):
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PiB"


def section_dryrun() -> str:
    rows = ["| arch | shape | mesh | step | compile_s | bytes/dev (args+tmp) | HLO flops/dev | coll bytes/dev |",
            "|---|---|---|---|---|---|---|---|"]
    for r in _load_dryrun():
        if "error" in r:
            rows.append(f"| {r['arch']} | {r['shape']} | {r.get('mesh','?')} "
                        f"| SKIP | — | {r['error'][:60]} | | |")
            continue
        mem = r.get("memory", {})
        args_b = mem.get("argument_size_in_bytes", 0)
        tmp_b = mem.get("temp_size_in_bytes", 0)
        coll = r.get("collective_bytes_per_device_total")
        if coll is None:
            coll = r["collective_bytes_per_device"]["total"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['step']} "
            f"| {r['compile_s']} | {_fmt_bytes(args_b)}+{_fmt_bytes(tmp_b)} "
            f"| {r['flops_per_device']:.3g} | {coll:.3g} |")
    return "\n".join(rows)


def section_roofline() -> str:
    from benchmarks.roofline import table
    rows = table()
    out = ["| arch | shape | mesh | compute_s | memory_s | collective_s "
           "| dominant | model TFLOPs | useful % | bound step_s |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["mesh"], r["arch"], r["shape"])):
        if "error" in r:
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | {r['dominant'].removesuffix('_s')} "
            f"| {r['model_flops'] / 1e12:.1f} | {100 * r['useful_ratio']:.1f} "
            f"| {r['step_s_bound']:.3e} |")
    return "\n".join(out)


def section_backend_sweep() -> str:
    """Seconds/round for the four execution backends (fl.backends) plus
    the donation memory comparison."""
    fn = os.path.join(RESULTS, "results", "backend_sweep.json")
    if not os.path.exists(fn):
        return ""
    with open(fn) as f:
        res = json.load(f)
    out = ["### backend_sweep (s/round)\n",
           "| cohort | dense | chunked | shard_map | temporal | devices |",
           "|---|---|---|---|---|---|"]
    cohorts = {k: v for k, v in res.items() if k.startswith("cohort_")}
    for setting, row in sorted(cohorts.items(),
                               key=lambda kv: int(kv[0].split("_")[-1])):
        if not isinstance(row, dict):
            continue
        cells = []
        for b in ("dense", "chunked", "shard_map", "temporal"):
            d = row.get(b)
            cells.append(f"{d['wall_per_round_s']:.3f}"
                         if isinstance(d, dict) else "—")
        dev = next((d.get("devices") for d in row.values()
                    if isinstance(d, dict)), "?")
        out.append(f"| {setting.removeprefix('cohort_')} | "
                   + " | ".join(cells) + f" | {dev} |")
    comp = res.get("compression")
    if isinstance(comp, dict) and comp:
        out += ["", "compressed client->server payloads "
                    "(reduced LM arch, dense backend; "
                    "bytes are analytic/deterministic):", "",
                "| mode | wire bytes/round | vs dense f32 | s/round "
                "| final acc |",
                "|---|---|---|---|---|"]
        for mode in ("none", "int8", "topk8"):
            d = comp.get(mode)
            if not isinstance(d, dict):
                continue
            ratio = (f"{d['wire_ratio']:.2f}x"
                     if d.get("wire_ratio") else "—")
            acc = (f"{d['final_acc']:.4f}"
                   if isinstance(d.get("final_acc"), (int, float)) else "—")
            out.append(f"| {mode} | "
                       f"{_fmt_bytes(d['bytes_per_round_wire'])} | {ratio} "
                       f"| {d['wall_per_round_s']:.3f} | {acc} |")
    don = res.get("donation")
    if isinstance(don, dict) and don:
        out += ["", "donated params buffers (compiled peak bytes, "
                    "donated / undonated):", ""]
        for name, row in sorted(don.items()):
            if isinstance(row, dict) and "peak_ratio" in row:
                out.append(f"* {name}: {row['donated_peak_bytes']:,} / "
                           f"{row['undonated_peak_bytes']:,} "
                           f"(x{row['peak_ratio']})")
    out.append("")
    return "\n".join(out)


def section_lm_smoke() -> str:
    """The federated LM driver on the unified runtime, per backend."""
    fn = os.path.join(RESULTS, "results", "lm_smoke.json")
    if not os.path.exists(fn):
        return ""
    with open(fn) as f:
        res = json.load(f)
    out = ["### lm_smoke (reduced-arch federated LM on RoundRuntime)\n",
           "| backend | arch | rounds | token loss | token acc | s/round |",
           "|---|---|---|---|---|---|"]
    for backend, d in sorted(res.items()):
        if not isinstance(d, dict):
            continue
        num = lambda k: (f"{d[k]:.4f}"
                         if isinstance(d.get(k), (int, float)) else "—")
        out.append(f"| {backend} | {d.get('arch', '?')} | "
                   f"{d.get('rounds', '?')} | {num('final_loss')} "
                   f"| {num('final_acc')} "
                   f"| {num('wall_per_round_s')} |")
    out.append("")
    return "\n".join(out)


def section_replan_sweep() -> str:
    """Static offline schedule vs online re-planning triggers
    (repro.core.replan) under the same T_max."""
    fn = os.path.join(RESULTS, "results", "replan_sweep.json")
    if not os.path.exists(fn):
        return ""
    with open(fn) as f:
        res = json.load(f)
    out = ["### replan_sweep (final accuracy under the same T_max)\n",
           "| scenario | never | every-k | drift | re-solves (e-k/drift) | "
           "budget used (never) |",
           "|---|---|---|---|---|---|"]
    for scn, row in sorted(res.items()):
        if not isinstance(row, dict):
            continue
        cells, resolves = [], []
        for trig in ("never", "every-k", "drift"):
            d = row.get(trig)
            if isinstance(d, dict) and d.get("accuracy"):
                cells.append(f"{d['accuracy'][-1]:.3f}")
                if trig != "never":
                    resolves.append(str(len(d.get("replans", []))))
            else:
                cells.append("—")
                if trig != "never":
                    resolves.append("—")
        never = row.get("never", {})
        used = (f"{never['times'][-1]:.1f}"
                if isinstance(never, dict) and never.get("times") else "—")
        out.append(f"| {scn} | " + " | ".join(cells)
                   + f" | {'/'.join(resolves)} | {used} |")
    out.append("")
    return "\n".join(out)


def section_async_sweep() -> str:
    """Round-synchronous vs buffered semi-async aggregation under the same
    ``T_max`` (``benchmarks/async_sweep.py``): final accuracy per arm plus
    the carry-buffer staleness statistics of the buffered arms."""
    fn = os.path.join(RESULTS, "results", "async_sweep.json")
    if not os.path.exists(fn):
        return ""
    with open(fn) as f:
        res = json.load(f)
    out = ["### async_sweep (staleness-weighted delayed gradients, "
           "same T_max)\n",
           "carried in = buffered late contributions folded into a later "
           "round's update (weight lam**tau); stale_mean = their mean "
           "staleness in rounds; dropped = expired (> max_age) or "
           "ring-evicted.\n",
           "| scenario | adel-sync | salf-buffered | adel-buffered | "
           "carried in (salf/adel) | stale_mean | dropped |",
           "|---|---|---|---|---|---|---|"]
    for scn, row in sorted(res.items()):
        if not isinstance(row, dict):
            continue
        cells, carried, stale, dropped = [], [], [], []
        for arm in ("adel-sync", "salf-buffered", "adel-buffered"):
            d = row.get(arm)
            if isinstance(d, dict) and d.get("accuracy"):
                cells.append(f"{d['accuracy'][-1]:.3f}")
            else:
                cells.append("—")
            if arm != "adel-sync" and isinstance(d, dict):
                drift = (d.get("telemetry") or {}).get("drift", {})
                carried.append(str(drift.get("carried_in_total", "—")))
                if "stale_mean" in drift:
                    stale.append(f"{drift['stale_mean']:.2f}")
                dropped.append(str(drift.get("carried_dropped_total", "—")))
        out.append(f"| {scn} | " + " | ".join(cells)
                   + f" | {'/'.join(carried) or '—'}"
                   + f" | {'/'.join(stale) or '—'}"
                   + f" | {'/'.join(dropped) or '—'} |")
    out.append("")
    return "\n".join(out)


def section_telemetry() -> str:
    """Round-runtime telemetry recorded by the instrumented suites
    (``History.telemetry`` blocks inside ``fleet_smoke.json``).

    Columns: *predicted* is the exponential clock model's forecast
    (expected backprop depth ``E[min(z, L)]`` from the planned deadline,
    Eq. 5), *simulated* is the straggler-draw clock the runtime charges
    against ``T_max``, and *wall* is measured host time
    (``time.perf_counter``); the drift columns quantify how far the
    realized draws land from the model the Problem-2 solver planned with.
    """
    fn = os.path.join(RESULTS, "results", "fleet_smoke.json")
    if not os.path.exists(fn):
        return ""
    with open(fn) as f:
        res = json.load(f)
    rows = []
    for setting, methods in sorted(res.items()):
        if not isinstance(methods, dict):
            continue
        for method, d in sorted(methods.items()):
            tel = d.get("telemetry") if isinstance(d, dict) else None
            if not tel or not tel.get("drift"):
                continue
            drift = tel["drift"]
            phases = tel.get("phases", {})
            train_s = sum(phases.get(p, {}).get("total_s", 0.0)
                          for p in ("local_train", "aggregate"))
            other_s = sum(v.get("total_s", 0.0)
                          for k, v in phases.items()
                          if k not in ("local_train", "aggregate"))
            ctr = tel.get("counters", {})
            logical = ctr.get("aggregate_bytes_logical", 0)
            wire = ctr.get("aggregate_bytes_wire", 0)
            if wire:
                bytes_cell = (f"{_fmt_bytes(logical)}/{_fmt_bytes(wire)} "
                              f"({logical / wire:.1f}x)")
            else:
                bytes_cell = "—"
            rows.append(
                f"| {setting} | {method} | {drift.get('rounds', '—')} "
                f"| {train_s:.2f}/{other_s:.2f} "
                f"| {bytes_cell} "
                f"| {drift.get('depth_drift_mean', '—')} "
                f"| {drift.get('miss_rate', '—')} "
                f"| {drift.get('zero_rate', '—')} "
                f"| {drift.get('deadline_vs_full_wait', '—')} |")
    if not rows:
        return ""
    out = ["### telemetry (round-runtime phase spans + clock-model drift)\n",
           "predicted = exponential-model forecast at the planned deadline; "
           "simulated = straggler-draw clock charged against T_max; "
           "wall = measured host perf_counter time. depth_drift = realized "
           "minus predicted backprop depth (layers, mean over rounds); "
           "deadline_vs_full_wait = planned deadline as a fraction of the "
           "synchronized full-depth wait (the paper's Eq. 5 saving). "
           "bytes logical/wire = dense-float32 payload the aggregation "
           "consumed vs compressed bytes on the wire "
           "(repro.core.compression), with the reduction ratio.\n",
           "| setting | method | rounds | train/other wall_s "
           "| bytes logical/wire | depth_drift | miss_rate | zero_rate "
           "| T_t/full_wait |",
           "|---|---|---|---|---|---|---|---|---|"]
    out += rows
    out.append("")
    return "\n".join(out)


def section_repro() -> str:
    out = []
    for name in ("fig2_mnist", "fig3_cifar", "fig4_robustness",
                 "table2_budgets", "fleet_smoke", "fleet_scenarios"):
        fn = os.path.join(RESULTS, "results", f"{name}.json")
        if not os.path.exists(fn):
            continue
        with open(fn) as f:
            res = json.load(f)
        out.append(f"### {name}\n")
        out.append("| setting | " + " | ".join(
            ["adel", "salf", "drop", "wait", "heterofl"]) + " |")
        out.append("|---|---|---|---|---|---|")
        for setting, methods in res.items():
            if not isinstance(methods, dict):
                continue
            cells = []
            for m in ("adel", "salf", "drop", "wait", "heterofl"):
                d = methods.get(m)
                if isinstance(d, dict) and d.get("accuracy"):
                    cells.append(f"{d['accuracy'][-1]:.3f}")
                elif isinstance(d, dict) and "final_acc" in d:
                    cells.append(f"{d['final_acc']:.3f}")
                else:
                    cells.append("—")
            out.append(f"| {setting} | " + " | ".join(cells) + " |")
        out.append("")
    sweep = section_backend_sweep()
    if sweep:
        out.append(sweep)
    replan = section_replan_sweep()
    if replan:
        out.append(replan)
    async_ = section_async_sweep()
    if async_:
        out.append(async_)
    lm = section_lm_smoke()
    if lm:
        out.append(lm)
    tel = section_telemetry()
    if tel:
        out.append(tel)
    return "\n".join(out)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--section", default="all",
                    choices=["all", "dryrun", "roofline", "repro",
                             "telemetry"])
    args = ap.parse_args(argv)
    if args.section == "telemetry":
        print("## Round-runtime telemetry\n")
        print(section_telemetry())
        return
    if args.section in ("all", "dryrun"):
        print("## Dry-run records\n")
        print(section_dryrun())
        print()
    if args.section in ("all", "roofline"):
        print("## Roofline\n")
        print(section_roofline())
        print()
    if args.section in ("all", "repro"):
        print("## Reproduction results\n")
        print(section_repro())


if __name__ == "__main__":
    main()
