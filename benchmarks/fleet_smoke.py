"""Fleet-smoke — fast end-to-end pass over two contrasting fleet scenarios
(churny long-tail mobile vs always-on datacenter) at reduced scale, plus
the int8-compressed twin of the mobile scenario (same population and
seeds; the matched-accuracy wire-compression comparison)."""
from __future__ import annotations

import dataclasses

from benchmarks.common import cached_result, events_path, save_result

SCENARIO_NAMES = ("longtail-mobile-diurnal", "datacenter-always-on",
                  "longtail-mobile-diurnal-int8")


def run(quick: bool = False) -> dict:
    cached = cached_result("fleet_smoke")
    if cached is not None:
        return cached
    from repro.fleet.scenarios import get_scenario, run_scenario

    fleet_size = 200 if quick else 400
    rounds = 4 if quick else 8
    result = {}
    for name in SCENARIO_NAMES:
        scn = get_scenario(name)
        scn = dataclasses.replace(scn, n_train=1200 if quick else 2500,
                                  n_test=400)
        print(f"[fleet_smoke] {name}: fleet={fleet_size} rounds={rounds}")
        hist = run_scenario(scn, rounds=rounds, fleet_size=fleet_size,
                            solver_steps=400, eval_every=2, verbose=False,
                            events=events_path(f"fleet_smoke.{name}"))
        acc = hist["accuracy"][-1] if hist["accuracy"] else 0.0
        print(f"  [{scn.method:9s}] rounds="
              f"{hist['rounds'][-1] if hist['rounds'] else 0}"
              f"  final_acc={acc:.4f}  wall={hist['wall_s']:.1f}s")
        result[name] = {scn.method: hist}
    base = result["longtail-mobile-diurnal"]["adel"]
    comp = result["longtail-mobile-diurnal-int8"]["adel"]
    if base.get("accuracy") and comp.get("accuracy"):
        a0, a1 = base["accuracy"][-1], comp["accuracy"][-1]
        print(f"[fleet_smoke] int8 wire vs dense f32 final acc: "
              f"{a1:.4f} vs {a0:.4f} (|diff| = {abs(a1 - a0):.4f}; "
              f"acceptance bound 0.02)")
    save_result("fleet_smoke", result)
    return result


if __name__ == "__main__":
    run()
