"""Pipeline sweep — serial vs prefetch round driver on two committed
configs, with the overlap accounting from the new pipeline counters.

The prefetch pipeline (``ExecSpec.pipeline="prefetch"``) overlaps round
``t+1``'s host planning/stacking with round ``t``'s device step, drains
eval scalars asynchronously, and AOT-warms the round step before the timed
loop. Two configs are swept, each in both modes:

* ``lm`` — the reduced-arch federated LM driver (same shape as
  ``lm_smoke``: dense backend, U=4, seq=32);
* ``fleet`` — a 10k-device parametric population with hierarchical
  two-tier aggregation (same shape as ``fleet_scale``'s smallest sweep).

Per mode the suite records total wall, steady-state s/round (for prefetch
the one-off ``warm_up_s`` counter is subtracted — it is reported
separately), final accuracy, and for prefetch the overlap fraction
(planning time hidden behind the device step over total wall). The two
modes must produce BIT-identical trajectories (``identical`` is asserted,
not just recorded); the headline ``speedup_pct`` compares steady-state
s/round. Emits ``experiments/results/pipeline_sweep.json`` plus one
telemetry stream per (config, mode) under ``events/``.
"""
from __future__ import annotations

from benchmarks.common import cached_result, events_path, save_result

ARCH = "qwen1.5-4b"
MODES = ("serial", "prefetch")


def _counters(hist) -> dict:
    return (hist.telemetry or {}).get("counters", {})


def _row(hist, wall: float, rounds: int, mode: str) -> dict:
    c = _counters(hist)
    warm = float(c.get("warm_up_s", 0.0)) if mode == "prefetch" else 0.0
    steady = max(wall - warm, 0.0)
    row = {
        "mode": mode,
        "rounds": rounds,
        "wall_s": round(wall, 4),
        # prefetch pays compile once in warm_up_s (reported below), so its
        # per-round number is the steady-state rate; serial's includes the
        # round-0 compile it cannot avoid
        "wall_per_round_s": round(steady / max(rounds, 1), 4),
        "final_acc": round(float(hist.accuracy[-1]), 4)
        if hist.accuracy else None,
        "accuracy": [round(float(a), 6) for a in hist.accuracy],
    }
    if mode == "prefetch":
        row["warm_up_s"] = round(warm, 4)
        row["prefetch_rounds"] = int(c.get("prefetch_rounds", 0))
        row["overlap_s"] = round(float(c.get("prefetch_overlap_s", 0.0)), 4)
        row["dispatch_wait_s"] = round(
            float(c.get("dispatch_wait_s", 0.0)), 4)
        row["overlap_frac"] = round(row["overlap_s"] / max(wall, 1e-9), 4)
        row["h2d_bytes"] = int(c.get("h2d_bytes", 0))
    return row


def _summarize(name: str, rows: dict) -> None:
    serial, prefetch = rows["serial"], rows["prefetch"]
    assert prefetch["accuracy"] == serial["accuracy"], \
        f"[pipeline_sweep] {name}: prefetch trajectory diverged from serial"
    rows["identical"] = True
    s, p = serial["wall_per_round_s"], prefetch["wall_per_round_s"]
    rows["speedup_pct"] = round(100.0 * (s - p) / max(s, 1e-9), 2)
    print(f"[pipeline_sweep] {name}: serial {s:.3f}s/round vs prefetch "
          f"{p:.3f}s/round (+{rows['speedup_pct']:.1f}%), "
          f"overlap={prefetch['overlap_s']:.3f}s "
          f"({100 * prefetch['overlap_frac']:.1f}% of wall), "
          f"warm_up={prefetch['warm_up_s']:.2f}s")


def run(quick: bool = False) -> dict:
    cached = cached_result("pipeline_sweep")
    if cached is not None:
        return cached
    from repro import obs
    from repro.data.synthetic import make_image_dataset
    from repro.fl.spec import ExecSpec
    from repro.fleet.engine import partition_fleet, run_fleet
    from repro.fleet.population import make_population
    from repro.launch.train import run_training
    from repro.models.paper_models import make_mlp

    result = {}

    lm_rounds = 6 if quick else 12
    lm = {}
    for mode in MODES:
        tracer = obs.make_tracer(events_path(f"pipeline_sweep.lm.{mode}"))
        t0 = obs.now()
        _, hist = run_training(
            ARCH, method="adel", rounds=lm_rounds, tmax=5.0 * lm_rounds,
            U=4, seq=32, eta0=1.0, seed=0, solver_steps=600, eval_every=1,
            verbose=False, exec=ExecSpec(pipeline=mode), tracer=tracer)
        lm[mode] = _row(hist, obs.now() - t0, lm_rounds, mode)
        tracer.close()
    _summarize("lm", lm)
    result["lm"] = lm

    fleet_rounds = 3 if quick else 5
    x_tr, y_tr, x_te, y_te = make_image_dataset(
        "mnist", n_train=800 if quick else 1600, n_test=300, seed=0,
        noise_std=1.0)
    data = partition_fleet(x_tr, y_tr, x_te, y_te, 64, alpha=0.5, seed=0)
    population = make_population(
        "parametric:longtail-mobile", size=10_000,
        availability="bernoulli", availability_kwargs=(("rate", 0.7),),
        regions=4)
    fleet = {}
    for mode in MODES:
        tracer = obs.make_tracer(events_path(f"pipeline_sweep.fleet.{mode}"))
        t0 = obs.now()
        _, hist = run_fleet(
            make_mlp(), population, data=data, method="adel",
            rounds=fleet_rounds, cohort_size=16, solver_steps=300,
            eval_every=1, seed=0, verbose=False,
            exec=ExecSpec(backend="hierarchical", regions=4, pipeline=mode),
            tracer=tracer)
        fleet[mode] = _row(hist, obs.now() - t0, fleet_rounds, mode)
        tracer.close()
    _summarize("fleet", fleet)
    result["fleet"] = fleet

    save_result("pipeline_sweep", result)
    return result


if __name__ == "__main__":
    run()
