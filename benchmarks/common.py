"""Shared harness for the paper-figure benchmarks (CPU-scale reruns of the
paper's experiments on synthetic data — see DESIGN.md §6)."""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import now
from repro.core.baselines import make_policy
from repro.core.scheduler import solve
from repro.core.types import AnalysisConfig
from repro.data.synthetic import make_image_dataset
from repro.fl.partition import dirichlet_partition, iid_partition, stack_clients
from repro.fl.server import run_federated

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "results")


def out_dir() -> str:
    """Where suite JSONs are written: ``REPRO_BENCH_OUT`` when set (the
    regression gate redirects fresh results away from the committed
    baselines it compares against), else the committed results dir."""
    return os.environ.get("REPRO_BENCH_OUT") or OUT_DIR


def events_path(name: str) -> str:
    """JSONL telemetry stream path for one benchmark run: the suites write
    their :mod:`repro.obs` event files under ``<out_dir>/events/`` so the
    regression gate's baseline-vs-fresh JSON diff never sees them, while CI
    uploads the whole directory and renders it with
    ``python -m repro.obs.timeline``."""
    d = os.path.join(out_dir(), "events")
    os.makedirs(d, exist_ok=True)
    return os.path.join(d, f"{name}.jsonl")


def save_result(name: str, payload: dict) -> str:
    d = out_dir()
    os.makedirs(d, exist_ok=True)
    path = os.path.join(d, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def cached_result(name: str) -> dict | None:
    """Return a previously saved result unless REPRO_BENCH_FORCE is set.

    The heavy CIFAR suites take ~1 h on this 1-core container; the final
    ``benchmarks.run`` pass reuses the recorded JSONs (stdout marks them
    [cached]) — set REPRO_BENCH_FORCE=1 to recompute everything.
    """
    if os.environ.get("REPRO_BENCH_FORCE"):
        return None
    path = os.path.join(out_dir(), f"{name}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        res = json.load(f)
    print(f"[{name}] [cached] loaded {path} "
          f"(REPRO_BENCH_FORCE=1 to recompute)")
    return res


def setup_fl(kind: str, model, *, U: int, R: int, T_max: float,
             eta0: float = 0.5, eta_decay: float = 1.0,
             alpha: float | None = 0.5,
             n_train: int = 2000, n_test: int = 500, seed: int = 0,
             depth_frac: float = 0.5):
    """Build data + AnalysisConfig. ``depth_frac`` calibrates T_max/R so the
    average backprop depth is that fraction of L (paper §IV-A/IV-B)."""
    x_tr, y_tr, x_te, y_te = make_image_dataset(
        kind, n_train=n_train, n_test=n_test, seed=seed, noise_std=1.0)
    if alpha is None:
        parts = iid_partition(len(y_tr), U, seed=seed)
    else:
        parts = dirichlet_partition(y_tr, U, alpha=alpha, seed=seed)
    cx, cy, counts = stack_clients(x_tr, y_tr, parts)
    cfg = AnalysisConfig.default(U=U, L=model.L, R=R, T_max=T_max,
                                 eta0=eta0, eta_decay=eta_decay, seed=seed)
    return cfg, (jnp.asarray(cx), jnp.asarray(cy), jnp.asarray(counts),
                 jnp.asarray(x_te), jnp.asarray(y_te))


def run_methods(model, cfg, data, methods, *, seed: int = 0,
                local_iters: int = 1, l2: float = 0.0,
                eta: np.ndarray | None = None, solver: str = "adam",
                eval_every: int = 2, verbose: bool = False):
    cx, cy, counts, x_te, y_te = data
    out = {}
    schedule = None
    for method in methods:
        t0 = now()
        if method == "adel" and schedule is None:
            schedule = solve(cfg, solver, **({"steps": 1200}
                                             if solver == "adam" else {}))
        policy = make_policy(method, cfg,
                             schedule=schedule if method == "adel" else None)
        _, hist = run_federated(model, policy, cfg, cx, cy, counts, x_te,
                                y_te, key=jax.random.PRNGKey(seed),
                                local_iters=local_iters, l2=l2, eta=eta,
                                eval_every=eval_every, verbose=verbose)
        d = hist.as_dict()
        d["wall_s"] = now() - t0
        if method == "adel":
            d["schedule_T"] = schedule.T.tolist()
            d["schedule_m"] = schedule.m
        out[method] = d
        print(f"  [{method:9s}] rounds={d['rounds'][-1] if d['rounds'] else 0}"
              f"  final_acc={d['accuracy'][-1] if d['accuracy'] else 0:.4f}"
              f"  wall={d['wall_s']:.1f}s")
    return out
