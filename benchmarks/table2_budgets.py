"""Table II — final accuracy vs total training budget T_max (CIFAR-like
VGG11, IID partition). The paper's budgets {1200..2400}s map to scaled
per-round-depth-equivalent budgets on the synthetic task."""
from __future__ import annotations

from benchmarks.common import (cached_result, run_methods, save_result,
                               setup_fl)
from repro.models.paper_models import make_vgg

METHODS = ["adel", "salf", "drop", "wait"]   # "wait" == FedAvg column


def run(quick: bool = False) -> dict:
    cached = cached_result("table2_budgets")
    if cached is not None:
        return cached
    R = 30 if quick else 60
    U = 8 if quick else 10
    model = make_vgg(11, width_scale=0.125)
    # paper budgets 1200/1600/2000/2400 s -> per-round depth ratios .5/.65/.8/1.
    fracs = [0.5, 0.8] if quick else [0.5, 0.65, 0.8, 1.0]
    result = {}
    for frac in fracs:
        T_max = R * model.L * frac
        cfg, data = setup_fl("cifar", model, U=U, R=R, T_max=T_max,
                             alpha=None, eta0=0.05, eta_decay=0.02,  # IID
                             n_train=800 if quick else 1000,
                             n_test=300 if quick else 400)
        print(f"[table2] T_max={T_max:.0f} (depth frac {frac})")
        rows = run_methods(model, cfg, data, METHODS, eval_every=10)
        result[f"budget_{frac}"] = {
            m: {"final_acc": (r["accuracy"][-1] if r["accuracy"] else 0.0)}
            for m, r in rows.items()}
        result[f"budget_{frac}"]["detail"] = rows
    save_result("table2_budgets", result)
    return result


if __name__ == "__main__":
    run()
