import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""§Perf hillclimbing harness: hypothesis -> change -> re-lower -> measure.

For a chosen (arch x shape) pair, lowers a sequence of VARIANTS (sharding
mode, fsdp, remat, attention window, client multiplexing ...) on the
single-pod mesh, extracts probe-corrected roofline terms for each, and
appends the iteration log to experiments/perf/<arch>__<shape>.json.

    PYTHONPATH=src python -m benchmarks.hillclimb --arch arctic-480b \
        --shape prefill_32k --variants base,fsdp_off
    PYTHONPATH=src python -m benchmarks.hillclimb --list
"""

import argparse
import json
import time

from repro.launch.costprobe import probe_combo
from repro.launch.dryrun import HBM_BW, ICI_BW, PEAK_FLOPS

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "perf")

# Each variant: (kwargs for probe_combo, hypothesis string).
VARIANTS = {
    "base": (dict(), "paper-faithful baseline (temporal clients, fsdp=data, "
                     "remat on)"),
    "fsdp_off": (dict(fsdp=None),
                 "replicate params instead of fsdp=data: removes per-layer "
                 "all-gather (collective term down) at the cost of "
                 "per-device parameter memory (memory analysis up)"),
    "no_remat": (dict(remat=False),
                 "disable activation rematerialization: compute term down "
                 "~25% (no forward recompute), temp memory up"),
    "spatial": (dict(mode="spatial"),
                "clients on the data axis (vmap) instead of the U-scan: "
                "same FLOPs, U-fold gradient memory, fewer accumulation "
                "round-trips (memory term shifts, collective unchanged)"),
    "swa4096": (dict(attn_window=4096),
                "sliding-window attention (w=4096): attention "
                "compute/memory term drops ~S/w for long sequences "
                "(beyond-paper variant for dense archs)"),
    "fsdp_off_no_remat": (dict(fsdp=None, remat=False),
                          "combine fsdp_off + no_remat"),
    "ssd_chunk256": (dict(cfg_overrides={"ssm_chunk": 256}),
                     "SSD chunk Q 64->256: inter-chunk state "
                     "materialization drops 4x (bytes ~ S/Q * h*N*P per "
                     "layer) while intra-chunk matmul bytes grow ~ S*Q — "
                     "net memory-term win when h*N*P >> Q*d_head"),
    "ssd_chunk32": (dict(cfg_overrides={"ssm_chunk": 32}),
                    "SSD chunk Q 64->32: opposite direction (control)"),
}


def roofline_of(corr: dict) -> dict:
    r = {"compute_s": corr["flops"] / PEAK_FLOPS,
         "memory_s": corr["bytes"] / HBM_BW,
         "collective_s": corr["coll"] / ICI_BW}
    r["dominant"] = max(("compute_s", "memory_s", "collective_s"),
                        key=lambda k: r[k])
    r["bound_s"] = r[r["dominant"]]
    return r


def run_pair(arch: str, shape: str, variant_names, *, multi_pod=False):
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{arch}__{shape}.json")
    log = []
    if os.path.exists(path):
        with open(path) as f:
            log = json.load(f)

    for name in variant_names:
        kw, hypothesis = VARIANTS[name]
        print(f"[hillclimb] {arch} x {shape} variant={name}: {hypothesis}",
              flush=True)
        t0 = time.time()
        try:
            res = probe_combo(arch, shape, multi_pod=multi_pod, **kw)
        except Exception as e:
            entry = {"variant": name, "hypothesis": hypothesis,
                     "error": f"{type(e).__name__}: {e}"}
            print(f"[hillclimb]   FAILED: {e}", flush=True)
            log.append(entry)
            continue
        roof = roofline_of(res["corrected"])
        entry = {"variant": name, "hypothesis": hypothesis,
                 "kwargs": {k: str(v) for k, v in kw.items()},
                 "corrected": res["corrected"], "roofline": roof,
                 "wall_s": round(time.time() - t0, 1)}
        log.append(entry)
        print(f"[hillclimb]   compute {roof['compute_s']:.3e}  memory "
              f"{roof['memory_s']:.3e}  coll {roof['collective_s']:.3e}  "
              f"dominant={roof['dominant']}  bound={roof['bound_s']:.3e}",
              flush=True)

    with open(path, "w") as f:
        json.dump(log, f, indent=1)
    # summary: best vs base on the dominant term
    ok = [e for e in log if "roofline" in e]
    if ok:
        base = next((e for e in ok if e["variant"] == "base"), ok[0])
        best = min(ok, key=lambda e: e["roofline"]["bound_s"])
        print(f"[hillclimb] {arch} x {shape}: base bound "
              f"{base['roofline']['bound_s']:.3e} -> best "
              f"{best['roofline']['bound_s']:.3e} ({best['variant']})",
              flush=True)
    return log


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--variants", default="base")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args(argv)
    if args.list:
        for k, (kw, h) in VARIANTS.items():
            print(f"{k:20s} {h}")
        return 0
    run_pair(args.arch, args.shape, args.variants.split(","),
             multi_pod=args.multi_pod)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
