"""Replan sweep — static offline schedule vs online re-planning triggers.

Runs the ``longtail-mobile-diurnal-replan`` scenario (one dominant time
zone: the reachable count swings to ~0 and night rounds skip entirely)
under the same ``T_max`` with the three ``repro.core.replan`` triggers:

* ``never``   — the static offline Problem-2 schedule (skipped rounds
                strand their deadline budget),
* ``every-k`` — periodic remaining-horizon re-solves,
* ``drift``   — re-solves when the reachable count moves past the
                threshold (the scenario's own default).

plus ``bimodal-edge-markov-replan`` (sticky Markov churn, every-k) in the
full pass. Emits ``experiments/results/replan_sweep.json``, rendered by
``benchmarks/report.py``; the CI regression gate checks the recorded
per-trigger final accuracies and wall-clocks stay put.
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import cached_result, events_path, save_result
from repro.core.replan import TRIGGERS


def _run_scenario_triggers(name: str, *, fleet_size: int, rounds: int,
                           n_train: int, solver_steps: int) -> dict:
    from repro.fleet.scenarios import get_scenario, run_scenario

    scn = get_scenario(name)
    scn = dataclasses.replace(scn, n_train=n_train, n_test=400)
    row = {}
    for trigger in TRIGGERS:
        hist = run_scenario(scn, rounds=rounds, fleet_size=fleet_size,
                            replan=trigger, solver_steps=solver_steps,
                            eval_every=2, verbose=False,
                            events=events_path(
                                f"replan_sweep.{name}.{trigger}"))
        acc = hist["accuracy"][-1] if hist["accuracy"] else 0.0
        used = hist["times"][-1] if hist["times"] else 0.0
        print(f"  [{trigger:8s}] final_acc={acc:.4f} "
              f"budget_used={used:.1f} replans={len(hist['replans'])} "
              f"wall={hist['wall_s']:.1f}s")
        row[trigger] = hist
    return row


def run(quick: bool = False) -> dict:
    cached = cached_result("replan_sweep")
    if cached is not None:
        return cached

    # rounds stays 14 even in quick mode: the scenario's diurnal period is
    # 14, and shortening the horizon parks the trough at the end of the run
    # where no recovery rounds remain to reclaim the stranded budget
    settings = dict(fleet_size=200 if quick else 300,
                    rounds=14,
                    n_train=1200 if quick else 2500,
                    solver_steps=400 if quick else 600)
    names = ["longtail-mobile-diurnal-replan"]
    if not quick:
        names.append("bimodal-edge-markov-replan")

    result = {}
    for name in names:
        print(f"[replan_sweep] {name}: fleet={settings['fleet_size']} "
              f"rounds={settings['rounds']}")
        result[name] = _run_scenario_triggers(name, **settings)
    save_result("replan_sweep", result)
    return result


if __name__ == "__main__":
    run()
