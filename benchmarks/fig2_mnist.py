"""Fig. 2 — MNIST-like MLP + CNN: adaptive deadline allocation (a, c) and
convergence vs baselines (b), inverse-decay LR, avg depth ~50%."""
from __future__ import annotations

from benchmarks.common import (cached_result, run_methods, save_result,
                               setup_fl)
from repro.models.paper_models import make_cnn, make_mlp

METHODS = ["adel", "salf", "drop", "wait", "heterofl"]


def run(quick: bool = False) -> dict:
    cached = cached_result("fig2_mnist")
    if cached is not None:
        return cached
    R = 20 if quick else 40
    U = 8 if quick else 10
    result = {}
    for arch, make, eta0 in [("mlp", make_mlp, 2.0), ("cnn", make_cnn, 0.3)]:
        if quick and arch == "cnn":
            continue
        model = make()
        # T_max/R tuned so T/m ~ L/2: avg backprop depth ~50% of layers
        cfg, data = setup_fl("mnist", model, U=U, R=R,
                             T_max=R * model.L * 0.5, alpha=0.5, eta0=eta0,
                             n_train=1200 if quick else 2500,
                             n_test=400 if quick else 800)
        print(f"[fig2] {arch}: U={U} R={R} T_max={cfg.T_max}")
        result[arch] = run_methods(model, cfg, data, METHODS)
    save_result("fig2_mnist", result)
    return result


if __name__ == "__main__":
    run()
