"""ADEL-FL on an assigned billion-scale architecture (reduced for CPU).

Runs REAL federated rounds of a reduced `--arch` config on synthetic token
streams through the unified round runtime: Problem-2 schedule -> straggler
depth draws (B1) -> deadline-truncated layer-wise aggregation (Eq. 5) ->
SGD. The round loop is the same :class:`repro.fl.runtime.RoundRuntime`
that serves the image and fleet workloads, so every execution backend
works here — ``--backend temporal`` is the grad-accumulation client
layout required for the big archs — and so do online re-planning
(``--replan every-k``) and HeteroFL (``--method heterofl``).

Run:  PYTHONPATH=src python examples/federated_llm_round.py --arch qwen1.5-4b
      (any of the 10 assigned --arch ids works; see repro/configs)
"""
import argparse
import math

from repro.fl.backends import BACKENDS
from repro.launch.train import run_training


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b")
    ap.add_argument("--method", default="adel",
                    choices=["adel", "salf", "drop", "wait", "heterofl"])
    ap.add_argument("--rounds", type=int, default=24)
    ap.add_argument("--tmax", type=float, default=120.0)
    ap.add_argument("--backend", default="temporal", choices=list(BACKENDS))
    ap.add_argument("--replan", default=None,
                    choices=["never", "every-k", "drift"])
    args = ap.parse_args()

    _, hist = run_training(args.arch, method=args.method, rounds=args.rounds,
                           tmax=args.tmax, U=6, seq=48, eta0=1.0,
                           backend=args.backend, replan=args.replan,
                           verbose=True)
    first, last = hist.train_loss[0], hist.train_loss[-1]
    print(f"\n[{args.arch}] {args.method} ({args.backend}): "
          f"token loss {first:.3f} -> {last:.3f} "
          f"(ppl {math.exp(min(last, 30)):.1f}, "
          f"token acc {hist.accuracy[-1]:.4f}) "
          f"over {hist.rounds[-1]} rounds "
          f"({hist.times[-1]:.1f}s simulated clock)")


if __name__ == "__main__":
    main()
