"""ADEL-FL on an assigned billion-scale architecture (reduced for CPU).

Runs REAL federated rounds of a reduced `--arch` config on synthetic token
streams: Problem-2 schedule -> straggler depth draws (B1) -> deadline-
truncated layer-wise aggregation (Eq. 5) -> SGD, via the same
``make_train_step`` that the multi-pod dry-run lowers at full scale.

Run:  PYTHONPATH=src python examples/federated_llm_round.py --arch qwen1.5-4b
      (any of the 10 assigned --arch ids works; see repro/configs)
"""
import argparse

from repro.launch.train import run_training


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b")
    ap.add_argument("--method", default="adel",
                    choices=["adel", "salf", "drop", "wait"])
    ap.add_argument("--rounds", type=int, default=24)
    ap.add_argument("--tmax", type=float, default=120.0)
    args = ap.parse_args()

    hist = run_training(args.arch, method=args.method, rounds=args.rounds,
                        tmax=args.tmax, U=6, client_batch=4, seq=48,
                        eta0=1.0, verbose=True)
    first, last = hist["loss"][0], hist["loss"][-1]
    print(f"\n[{args.arch}] {args.method}: loss {first:.3f} -> {last:.3f} "
          f"over {hist['round'][-1]} rounds "
          f"({hist['time'][-1]:.1f}s simulated clock)")


if __name__ == "__main__":
    main()
