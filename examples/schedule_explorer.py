"""Explore the Problem-2 deadline/batch solution space (paper Fig. 2a/3a).

Solves the ADEL-FL scheduling problem for several time budgets and
heterogeneity spreads, and prints the resulting deadline profiles — showing
the paper's headline qualitative result: deadlines DECREASE over rounds,
tracking the decaying learning rate (early rounds buy straggler depth when
updates matter most).

Run:  PYTHONPATH=src python examples/schedule_explorer.py
"""
import numpy as np

from repro.core.cost import b_term, c_term, theorem1_bound
from repro.core.scheduler import solve
from repro.core.types import AnalysisConfig


def spark(values, width: int = 40) -> str:
    blocks = " .:-=+*#%@"
    v = np.asarray(values, float)
    idx = np.linspace(0, len(v) - 1, width).astype(int)
    v = v[idx]
    t = (v - v.min()) / max(v.max() - v.min(), 1e-12)
    return "".join(blocks[int(x * (len(blocks) - 1))] for x in t)


def main():
    R, U, L = 30, 12, 10
    print(f"{'T_max':>7s} {'spread':>7s} {'m':>6s} "
          f"{'T_1':>6s} {'T_R':>6s}  deadline profile (round 1..R)")
    for t_max in (60.0, 120.0, 240.0):
        for spread in (2.0, 8.0):
            cfg = AnalysisConfig.default(U=U, L=L, R=R, T_max=t_max,
                                         eta0=0.5, seed=0,
                                         het_spread=spread)
            sch = solve(cfg, "adam", steps=800)
            print(f"{t_max:7.0f} {spread:7.1f} {sch.m:6.2f} "
                  f"{sch.T[0]:6.2f} {sch.T[-1]:6.2f}  {spark(sch.T)}")

    # decompose the Theorem-1 objective for one setting: B_t vs C_t trade-off
    cfg = AnalysisConfig.default(U=U, L=L, R=R, T_max=120.0, eta0=0.5, seed=0)
    sch = solve(cfg, "adam", steps=800)
    import jax.numpy as jnp
    T = jnp.asarray(sch.T)
    print("\nTheorem-1 terms at the optimum (round 1, mid, R):")
    bt = np.asarray(b_term(T, jnp.float32(sch.m), cfg))
    ct = np.asarray(c_term(T, jnp.float32(sch.m), cfg))
    for t in (0, R // 2, R - 1):
        print(f"  t={t + 1:2d}: B_t={bt[t]:9.3f}  C_t={ct[t]:9.3f}")
    print(f"objective (Theorem-1 bound) = "
          f"{float(theorem1_bound(T, jnp.float32(sch.m), cfg)):.4f}")

    print("\nm sensitivity (C_t explodes as m grows at fixed deadlines):")
    for m_try in (0.5 * sch.m, sch.m, 2.0 * sch.m, 4.0 * sch.m):
        val = float(theorem1_bound(T, jnp.float32(m_try), cfg))
        print(f"  m={m_try:6.2f}: bound={val:10.4f}"
              + ("   <- optimum" if abs(m_try - sch.m) < 1e-9 else ""))


if __name__ == "__main__":
    main()
