"""Two contrasting fleets, side by side.

Runs ADEL-FL against `longtail-mobile-diurnal` (heavy-tailed phone fleet
with day/night churn) and `datacenter-always-on` (homogeneous fast silo)
and prints the accuracy / deadline / availability trajectories next to
each other — the fleet substrate makes the *same* policy face radically
different populations.

``--replan`` turns on online deadline/batch re-planning
(repro.core.replan): the remaining-horizon Problem 2 is warm-start
re-solved when the trigger fires, so the schedule tracks the reachable
population instead of the offline plan.

Run:  PYTHONPATH=src python examples/fleet_scenarios.py [--rounds N]
      PYTHONPATH=src python examples/fleet_scenarios.py --replan drift
"""
import argparse
import dataclasses

from repro.fleet.scenarios import get_scenario, run_scenario

NAMES = ("longtail-mobile-diurnal", "datacenter-always-on")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--fleet-size", type=int, default=300)
    ap.add_argument("--backend", default=None,
                    choices=["dense", "chunked", "shard_map", "temporal"],
                    help="execution backend (repro.fl.backends); default "
                         "keeps the scenario's chunked engine")
    ap.add_argument("--replan", default=None,
                    choices=["never", "every-k", "drift"],
                    help="online re-planning trigger (repro.core.replan)")
    ap.add_argument("--replan-every", type=int, default=None,
                    help="every-k re-plan period")
    args = ap.parse_args()

    runs = {}
    for name in NAMES:
        scn = dataclasses.replace(get_scenario(name), n_train=2000, n_test=400)
        print(f"== running {name} "
              f"(fleet={args.fleet_size}, rounds={args.rounds}) ==")
        runs[name] = run_scenario(scn, rounds=args.rounds,
                                  fleet_size=args.fleet_size,
                                  backend=args.backend,
                                  replan=args.replan,
                                  replan_every=args.replan_every,
                                  solver_steps=400, verbose=False)

    a, b = (runs[n] for n in NAMES)
    print(f"\n{'':8s} | {NAMES[0]:^34s} | {NAMES[1]:^34s}")
    print(f"{'round':8s} | {'acc':>7s} {'deadline':>9s} {'avail':>6s} "
          f"{'':8s} | {'acc':>7s} {'deadline':>9s} {'avail':>6s}")
    for i in range(max(len(a["rounds"]), len(b["rounds"]))):
        def cells(r):
            if i >= len(r["rounds"]):
                return f"{'—':>7s} {'—':>9s} {'—':>6s} {'':8s}"
            return (f"{r['accuracy'][i]:7.4f} {r['deadlines'][i]:9.3f} "
                    f"{r['available'][i]:6d} {'':8s}")
        rnd = (a["rounds"][i] if i < len(a["rounds"])
               else b["rounds"][i])
        print(f"{rnd:<8d} | {cells(a)} | {cells(b)}")
    print(f"\nfinal: {NAMES[0]} acc={a['accuracy'][-1]:.4f} "
          f"({a['wall_s']:.1f}s wall), "
          f"{NAMES[1]} acc={b['accuracy'][-1]:.4f} ({b['wall_s']:.1f}s wall)")
    for name in NAMES:
        r = runs[name]
        if r["replans"]:
            print(f"  {name} re-planned at rounds "
                  f"{[ev['round'] + 1 for ev in r['replans']]} "
                  f"(m -> {[round(ev['m'], 2) for ev in r['replans']]})")
    print("The datacenter fleet sustains near-full availability and tight "
          "deadlines; the long-tail mobile fleet loses a third of its "
          "devices to the diurnal cycle and pays for its stragglers.")


if __name__ == "__main__":
    main()
