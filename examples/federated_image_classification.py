"""End-to-end driver: the paper's CIFAR-like experiment (Section IV-B).

Trains a (width-reduced) VGG11 across 12 Dirichlet(0.5) non-IID clients
under a total time budget, comparing ADEL-FL against every baseline the
paper uses (SALF / Drop-Stragglers / Wait-Stragglers), and prints an ASCII
convergence chart. This is the runnable counterpart of Fig. 3.

Run:  PYTHONPATH=src python examples/federated_image_classification.py
      [--rounds 20] [--methods adel,salf,drop,wait]
"""
import argparse
import json
import os

import jax
import jax.numpy as jnp

from repro.core.baselines import make_policy
from repro.core.scheduler import solve
from repro.core.types import AnalysisConfig
from repro.data.synthetic import make_image_dataset
from repro.fl.partition import dirichlet_partition, stack_clients
from repro.fl.server import run_federated
from repro.models.paper_models import make_vgg


def ascii_chart(histories: dict, width: int = 60, height: int = 12) -> str:
    t_max = max(h.times[-1] for h in histories.values())
    rows = [[" "] * width for _ in range(height)]
    marks = {}
    for i, (name, h) in enumerate(histories.items()):
        ch = name[0].upper()
        marks[ch] = name
        for t, a in zip(h.times, h.accuracy):
            x = min(int(t / t_max * (width - 1)), width - 1)
            y = min(int(a * (height - 1)), height - 1)
            rows[height - 1 - y][x] = ch
    lines = ["accuracy"]
    for r, row in enumerate(rows):
        frac = (height - 1 - r) / (height - 1)
        lines.append(f"{frac:4.2f} |" + "".join(row))
    lines.append("     +" + "-" * width + f"> time (0..{t_max:.0f}s)")
    lines.append("     " + "  ".join(f"{c}={n}" for c, n in marks.items()))
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--clients", type=int, default=12)
    ap.add_argument("--methods", default="adel,salf,drop,wait")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    model = make_vgg(11, width_scale=0.125)
    x_tr, y_tr, x_te, y_te = make_image_dataset(
        "cifar", n_train=1000, n_test=300, seed=0)
    parts = dirichlet_partition(y_tr, args.clients, alpha=0.5, seed=0)
    cx, cy, counts = stack_clients(x_tr, y_tr, parts)
    data = (jnp.asarray(cx), jnp.asarray(cy), jnp.asarray(counts),
            jnp.asarray(x_te), jnp.asarray(y_te))

    # avg backprop depth ~85% of layers, as in the paper's CIFAR setup;
    # slow inverse decay (deep conv net, few rounds — see EXPERIMENTS.md)
    cfg = AnalysisConfig.default(U=args.clients, L=model.L, R=args.rounds,
                                 T_max=args.rounds * model.L * 0.85,
                                 eta0=0.05, eta_decay=0.02, seed=0)
    schedule = solve(cfg, "adam", steps=800)
    print(f"[schedule] m={schedule.m:.2f}  "
          f"T: {schedule.T[0]:.2f} .. {schedule.T[-1]:.2f}")

    histories = {}
    for method in args.methods.split(","):
        policy = make_policy(method, cfg,
                             schedule=schedule if method == "adel" else None)
        _, hist = run_federated(model, policy, cfg, *data,
                                key=jax.random.PRNGKey(0), eval_every=2,
                                verbose=True)
        histories[method] = hist

    print()
    print(ascii_chart(histories))
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump({k: h.as_dict() for k, h in histories.items()}, f,
                      indent=1)
        print(f"saved {args.out}")


if __name__ == "__main__":
    main()
