"""Batched LLM serving with a KV/SSM cache (reduced arch on CPU).

Prefills a batch of prompts and greedy-decodes new tokens through the same
``serve_step`` that the decode_32k / long_500k dry-run shapes lower on the
production mesh.

Run:  PYTHONPATH=src python examples/serve_llm.py --arch mamba2-370m
      (try an SSM/hybrid arch for O(1)-state decode, or a dense GQA arch)
"""
import argparse

from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-370m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=48)
    args = ap.parse_args()
    out = serve(args.arch, batch=args.batch, prompt_len=args.prompt_len,
                new_tokens=args.new_tokens)
    print(f"[done] {out['arch']}: {out['tok_per_s']:.1f} tok/s")


if __name__ == "__main__":
    main()
