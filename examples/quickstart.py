"""Quickstart: the ADEL-FL pipeline in ~60 lines.

1. Build the analysis constants (Table I of the paper).
2. Solve Problem 2 (jointly optimal deadlines {T_t^d} and batch scale m).
3. Run a small federated round loop (layer-wise aggregation, Eq. 5) on a
   synthetic MNIST-like task and compare ADEL-FL against Drop-Stragglers.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.baselines import make_policy
from repro.core.scheduler import solve
from repro.core.types import AnalysisConfig
from repro.data.synthetic import make_image_dataset
from repro.fl.partition import dirichlet_partition, stack_clients
from repro.fl.server import run_federated
from repro.models.paper_models import make_mlp


def main():
    # --- data: 10 clients, Dirichlet(0.5) non-IID split -------------------
    x_tr, y_tr, x_te, y_te = make_image_dataset(
        "mnist", n_train=1500, n_test=400, seed=0)
    U = 10
    parts = dirichlet_partition(y_tr, U, alpha=0.5, seed=0)
    cx, cy, counts = stack_clients(x_tr, y_tr, parts)

    # --- model + analysis constants (A1-A3, B1-B3) ------------------------
    model = make_mlp()
    cfg = AnalysisConfig.default(U=U, L=model.L, R=25, T_max=60.0,
                                 eta0=2.0, seed=3)

    # --- Problem 2: optimal deadlines + batch scale ------------------------
    schedule = solve(cfg, "adam", steps=800)
    print(f"batch scale m = {schedule.m:.3f}")
    print("deadlines T_t^d:", np.round(schedule.T[:6], 2), "...",
          np.round(schedule.T[-3:], 2))
    print("batch sizes S_1^u:", schedule.batch_sizes(cfg)[0])

    # --- run ADEL-FL vs Drop-Stragglers ------------------------------------
    data = (jnp.asarray(cx), jnp.asarray(cy), jnp.asarray(counts),
            jnp.asarray(x_te), jnp.asarray(y_te))
    for method in ("adel", "drop"):
        policy = make_policy(method, cfg,
                             schedule=schedule if method == "adel" else None)
        _, hist = run_federated(model, policy, cfg, *data,
                                key=jax.random.PRNGKey(0), eval_every=5)
        print(f"[{method:5s}] final accuracy {hist.accuracy[-1]:.3f} "
              f"after {hist.rounds[-1]} rounds "
              f"({hist.times[-1]:.1f}s simulated)")


if __name__ == "__main__":
    main()
