"""Per-architecture smoke tests: a REDUCED variant of each assigned family
(2 layers, d_model <= 512, <= 4 experts) runs one forward + one federated
train step on CPU; output shapes check out and nothing is NaN."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import transformer as tr
from repro.launch.steps import make_train_step, make_serve_step

ARCH_NAMES = sorted(ARCHS)


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


def _front(cfg, key, U=None, b=2):
    if cfg.frontend == "none":
        return None
    shape = ((U, b, cfg.n_frontend_tokens, cfg.d_model) if U
             else (b, cfg.n_frontend_tokens, cfg.d_model))
    return 0.02 * jax.random.normal(key, shape)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_reduced_constraints(name):
    r = ARCHS[name].reduced()
    assert r.L == 2
    assert r.d_model <= 512
    assert r.n_experts <= 4
    assert r.n_heads % r.n_kv == 0


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_shapes_no_nan(name, key):
    cfg = ARCHS[name].reduced()
    params = tr.init_params(key, cfg)
    B, S = 2, 32
    tok = jax.random.randint(key, (B, S), 0, cfg.vocab)
    logits, aux = tr.forward(params, cfg, tok, frontend=_front(cfg, key))
    S_out = S + (cfg.n_frontend_tokens if cfg.frontend == "vision" else 0)
    assert logits.shape == (B, S_out, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_federated_train_step(name, key):
    """One ADEL federated round on the reduced config: loss drops params
    change, everything finite."""
    cfg = ARCHS[name].reduced()
    params = tr.init_params(key, cfg)
    U, b, S = 3, 2, 16
    L_tot = cfg.n_blocks_total
    tok = jax.random.randint(key, (U, b, S), 0, cfg.vocab)
    lab = jax.random.randint(key, (U, b, S), 0, cfg.vocab)
    mask = jnp.ones((U, L_tot), jnp.float32).at[0, 0].set(0.0)
    p = jnp.full((L_tot,), 0.05, jnp.float32)
    step = make_train_step(cfg, U=U, mode="temporal", remat=False)
    args = [params, tok, lab, mask, p, jnp.float32(0.1)]
    if cfg.frontend != "none":
        args.append(_front(cfg, key, U=U, b=b))
    new_params = jax.jit(step)(*args)
    leaves_old = jax.tree.leaves(params)
    leaves_new = jax.tree.leaves(new_params)
    assert all(np.isfinite(np.asarray(l, np.float32)).all()
               for l in leaves_new)
    changed = sum(bool(np.any(np.asarray(a) != np.asarray(bb)))
                  for a, bb in zip(leaves_old, leaves_new))
    assert changed > 0


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_serve_step(name, key):
    cfg = ARCHS[name].reduced()
    params = tr.init_params(key, cfg)
    B = 2
    cache = tr.init_cache(cfg, B, 32, dtype=jnp.float32)
    if cfg.enc_layers:
        frames = 0.02 * jax.random.normal(
            key, (B, cfg.n_frontend_tokens, cfg.d_model))
        enc_out = tr._run_encoder(params, cfg, frames, jnp.dtype(cfg.dtype))
        cache = cache._replace(cross=tr.build_cross_cache(params, cfg, enc_out))
    tok = jax.random.randint(key, (B,), 0, cfg.vocab)
    step = jax.jit(make_serve_step(cfg))
    nxt, cache2 = step(params, cache, tok, jnp.int32(0))
    assert nxt.shape == (B,)
    assert nxt.dtype == jnp.int32
    nxt2, _ = step(params, cache2, nxt, jnp.int32(1))
    assert np.isfinite(np.asarray(nxt2, np.float32)).all()
