"""Pipelined round driver (``ExecSpec.pipeline="prefetch"``).

The one-round-lookahead prefetcher speculates only on host-deterministic
phases, so its trajectories must be BIT-identical to serial — not merely
close — on every backend, including the buffered backend's carry ring and
the hierarchical backend's region folds, and across skipped rounds and
mid-run replans (which force a serial-fallback round). The pipeline
counters (``h2d_bytes`` / ``prefetch_overlap_s`` / ``dispatch_wait_s`` /
``warm_up_s``) and the AOT warm-up span must land in the event stream.
"""
import argparse

import jax
import jax.numpy as jnp
import pytest

from repro.core.baselines import make_policy
from repro.core.replan import ReplanConfig
from repro.core.scheduler import solve
from repro.core.types import AnalysisConfig
from repro.data.synthetic import make_image_dataset
from repro.fl.partition import dirichlet_partition, stack_clients
from repro.fl.runtime import RoundRuntime, StaticCohortSource
from repro.fl.server import run_federated
from repro.fl.spec import ExecSpec
from repro.models.paper_models import make_mlp
from repro.obs import MemorySink, Tracer

R = 4
U = 8

# every backend, with the knobs that exercise its stateful paths: the
# buffered carry ring actually banking (lam > 0) and the hierarchical
# region split actually splitting (regions > 1, no population ids)
BACKEND_SPECS = [
    dict(backend="dense"),
    dict(backend="chunked", chunk_size=3),
    dict(backend="shard_map"),
    dict(backend="temporal"),
    dict(backend="buffered", lam=0.5, max_age=3, buffer_cap=3),
    dict(backend="hierarchical", regions=3),
]


@pytest.fixture(scope="module")
def setup():
    x_tr, y_tr, x_te, y_te = make_image_dataset(
        "mnist", n_train=400, n_test=100, seed=0, noise_std=1.0)
    parts = dirichlet_partition(y_tr, U, alpha=0.5, seed=0)
    cx, cy, counts = stack_clients(x_tr, y_tr, parts)
    model = make_mlp()
    cfg = AnalysisConfig.default(U=U, L=model.L, R=R, T_max=R * model.L * 0.5,
                                 eta0=2.0, seed=0)
    data = (jnp.asarray(cx), jnp.asarray(cy), jnp.asarray(counts),
            jnp.asarray(x_te), jnp.asarray(y_te))
    schedule = solve(cfg, "adam", steps=100)
    return model, cfg, data, schedule


def _run(setup, pipeline, backend_kw, tracer=None, replan=None):
    model, cfg, data, schedule = setup
    policy = make_policy("adel", cfg, schedule=schedule)
    _, hist = run_federated(model, policy, cfg, *data,
                            key=jax.random.PRNGKey(0),
                            exec=ExecSpec(pipeline=pipeline, **backend_kw),
                            tracer=tracer, replan=replan)
    return hist


def _assert_bit_identical(a, b):
    # the whole History, exact: clock, plans, accuracy, losses, replans
    assert a.as_dict() == b.as_dict()


@pytest.mark.parametrize("backend_kw", BACKEND_SPECS,
                         ids=[s["backend"] for s in BACKEND_SPECS])
def test_prefetch_bit_identical_to_serial(setup, backend_kw):
    _assert_bit_identical(_run(setup, "serial", backend_kw),
                          _run(setup, "prefetch", backend_kw))


def test_history_holds_plain_floats(setup):
    """The pending eval ring must be fully drained by the time run()
    returns — downstream consumers json-serialize History as-is."""
    hist = _run(setup, "prefetch", dict(backend="dense"))
    assert all(isinstance(v, float) for v in hist.accuracy)
    assert all(isinstance(v, float) for v in hist.train_loss)


def test_prefetch_skip_and_forced_replan(setup):
    """An empty-cohort round and the skip-forced re-solve at the next
    executed round (both of which mutate the planning state) must leave
    the prefetched trajectory bit-identical — the driver falls back to
    inline planning for the round after a skip/replan."""
    model, cfg, data, schedule = setup
    cx, cy, counts, x_te, y_te = data

    class SkippySource(StaticCohortSource):
        def round_cohort(self, t):
            return None if t == 1 else super().round_cohort(t)

    def run(pipeline):
        policy = make_policy("adel", cfg, schedule=schedule)
        runtime = RoundRuntime(model, policy,
                               exec=ExecSpec(pipeline=pipeline))
        _, hist = runtime.run(
            SkippySource(cx, cy, counts), rounds=cfg.R, T_max=cfg.T_max,
            eta=cfg.eta, s_max=16, key=jax.random.PRNGKey(0),
            test_x=x_te, test_y=y_te,
            replan=ReplanConfig(trigger="drift", drift_threshold=10.0,
                                steps=80))
        return hist

    a, b = run("serial"), run("prefetch")
    _assert_bit_identical(a, b)
    # the scenario actually exercised both fallback paths
    assert len(a.replans) == 1 and a.replans[0]["round"] == 2


def test_prefetch_counters_and_warmup(setup):
    """A traced prefetch run records the pipeline counters (all nonzero),
    the warm_up span, and one prefetched round per lookahead."""
    sink = MemorySink()
    hist = _run(setup, "prefetch", dict(backend="dense"),
                tracer=Tracer(sink))
    c = hist.telemetry["counters"]
    assert c["h2d_bytes"] > 0
    assert c["warm_up_s"] > 0
    assert c["prefetch_rounds"] == R - 1        # round 0 planned inline
    assert c["prefetch_overlap_s"] > 0
    assert "dispatch_wait_s" in c
    assert "warm_up" in hist.telemetry["phases"]
    # worker-planned phases are re-emitted on the main thread with the
    # right round stamp
    spans = [r for r in sink.records if r.get("kind") == "span"]
    assert {r["name"] for r in spans} >= {"warm_up", "cohort", "plan",
                                          "stack", "eval"}
    plan_rounds = sorted({r["round"] for r in spans
                          if r["name"] == "plan"})
    assert plan_rounds == list(range(1, R + 1))


def test_serial_counters_absent(setup):
    """Serial mode never engages the prefetcher or the warm-up."""
    hist = _run(setup, "serial", dict(backend="dense"),
                tracer=Tracer(MemorySink()))
    c = hist.telemetry["counters"]
    assert "prefetch_rounds" not in c
    assert "warm_up_s" not in c
    assert c["h2d_bytes"] > 0            # stacked-bytes counter is modal-
    assert "warm_up" not in hist.telemetry["phases"]   # independent


def test_exec_spec_pipeline_validation_and_cli():
    with pytest.raises(ValueError):
        ExecSpec(pipeline="bogus")
    ap = argparse.ArgumentParser()
    ExecSpec.add_cli_args(ap)
    args = ap.parse_args(["--pipeline", "prefetch"])
    assert ExecSpec.from_cli(args).pipeline == "prefetch"
    assert ExecSpec.from_cli(ap.parse_args([])).pipeline == "serial"
    # --compile-cache is a process-level jax flag, not a spec field
    args = ap.parse_args(["--compile-cache", ""])
    assert not hasattr(ExecSpec.from_cli(args), "compile_cache")
