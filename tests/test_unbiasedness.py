"""Lemma 2 (unbiasedness): E[w~_{t+1}] = w_{t+1} (vanilla FedAvg), given the
batches — the aggregation randomness is only the straggler draw."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import aggregate_grads
from repro.core.straggler import contribution_mask, exact_p_layers, sample_depths


def test_unbiased_montecarlo():
    U, L, F = 8, 5, 12
    key = jax.random.PRNGKey(0)
    g = jax.random.normal(key, (U, L, F))          # fixed client grads
    ids = jnp.arange(L)
    # B3 batch scaling EQUALIZES the per-user Poisson rates (lambda_u ~ T/m
    # for every u) — this exchangeability is what makes the masked mean
    # conditionally unbiased (Lemma 4 of [18], invoked in Appendix B). With
    # heterogeneous rates the layer-wise mean would tilt toward fast
    # clients; see DESIGN.md §Faithfulness-notes.
    lam = jnp.full((U,), 5.0, jnp.float32)
    p = exact_p_layers(lam, L)                     # (L,)
    fedavg = g.mean(0)                             # full participation

    n = 6000
    keys = jax.random.split(jax.random.PRNGKey(42), n)

    def one(k):
        z = sample_depths(k, lam)
        mask = contribution_mask(z, L)
        return aggregate_grads({"w": g}, {"w": ids}, mask, p)["w"]

    agg = jax.vmap(one)(keys)                      # (n, L, F)
    mean = np.asarray(agg.mean(0))
    se = np.asarray(agg.std(0)) / np.sqrt(n)
    err = np.abs(mean - np.asarray(fedavg))
    # Eq. (5) in gradient form: E[g~^l] = (1-p_l) * mean_masked / (1-p_l) = g^l
    assert np.all(err <= 4.5 * se + 2e-3), (err.max(), se.max())


def test_layer_preserved_when_empty():
    """ADEL-FL preserves layer params when no updates arrive (g~ = 0),
    unlike SALF's default FedAvg fallback."""
    U, L, F = 4, 3, 7
    g = jnp.ones((U, L, F))
    mask = jnp.ones((U, L)).at[:, 0].set(0.0)      # nobody reached layer 1
    p = jnp.asarray([0.9, 0.1, 0.0])
    agg = aggregate_grads({"w": g}, {"w": jnp.arange(L)}, mask, p)["w"]
    np.testing.assert_allclose(np.asarray(agg[0]), 0.0)     # preserved
    np.testing.assert_allclose(np.asarray(agg[1]),
                               1.0 / (1 - 0.1), rtol=1e-6)  # corrected
    np.testing.assert_allclose(np.asarray(agg[2]), 1.0, rtol=1e-6)
