"""Lemma 2 (unbiasedness): E[w~_{t+1}] = w_{t+1} (vanilla FedAvg), given the
batches — the aggregation randomness is only the straggler draw. The same
property holds for the buffered backend's LATE-set fold (the complement
mask with the late-set zero-contributor probabilities) at staleness
weight 1."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import (aggregate_grads, aggregate_with_coeffs,
                                    layer_coefficients)
from repro.core.straggler import (contribution_mask, exact_p_layers,
                                  late_p_layers, sample_depths)


def test_unbiased_montecarlo():
    U, L, F = 8, 5, 12
    key = jax.random.PRNGKey(0)
    g = jax.random.normal(key, (U, L, F))          # fixed client grads
    ids = jnp.arange(L)
    # B3 batch scaling EQUALIZES the per-user Poisson rates (lambda_u ~ T/m
    # for every u) — this exchangeability is what makes the masked mean
    # conditionally unbiased (Lemma 4 of [18], invoked in Appendix B). With
    # heterogeneous rates the layer-wise mean would tilt toward fast
    # clients; see DESIGN.md §Faithfulness-notes.
    lam = jnp.full((U,), 5.0, jnp.float32)
    p = exact_p_layers(lam, L)                     # (L,)
    fedavg = g.mean(0)                             # full participation

    n = 6000
    keys = jax.random.split(jax.random.PRNGKey(42), n)

    def one(k):
        z = sample_depths(k, lam)
        mask = contribution_mask(z, L)
        return aggregate_grads({"w": g}, {"w": ids}, mask, p)["w"]

    agg = jax.vmap(one)(keys)                      # (n, L, F)
    mean = np.asarray(agg.mean(0))
    se = np.asarray(agg.std(0)) / np.sqrt(n)
    err = np.abs(mean - np.asarray(fedavg))
    # Eq. (5) in gradient form: E[g~^l] = (1-p_l) * mean_masked / (1-p_l) = g^l
    assert np.all(err <= 4.5 * se + 2e-3), (err.max(), se.max())


def test_late_fold_unbiased_montecarlo():
    """The buffered backend's delayed-gradient fold is unbiased: Eq. 5's
    coefficient path applied to the LATE mask ``1 - mask`` with the
    late-set zero-contributor probabilities
    (:func:`repro.core.straggler.late_p_layers`) estimates the same
    FedAvg layer mean — so at staleness weight ``w(tau) = 1`` the carried
    fold adds an unbiased estimate of exactly the update the synchronous
    round discarded."""
    U, L, F = 8, 5, 12
    g = jax.random.normal(jax.random.PRNGKey(1), (U, L, F))
    ids = jnp.arange(L)
    lam = jnp.full((U,), 5.0, jnp.float32)   # exchangeable rates (B3)
    p_late = late_p_layers(lam, L)           # (L,)
    fedavg = g.mean(0)

    n = 6000
    keys = jax.random.split(jax.random.PRNGKey(43), n)

    def one(k):
        z = sample_depths(k, lam)
        late = 1.0 - contribution_mask(z, L)          # layers missed at T_d
        coeffs = layer_coefficients(late, p_late)     # Eq. 5 on the late set
        return aggregate_with_coeffs({"w": g}, {"w": ids}, coeffs)["w"]

    agg = jax.vmap(one)(keys)                          # (n, L, F)
    mean = np.asarray(agg.mean(0))
    se = np.asarray(agg.std(0)) / np.sqrt(n)
    err = np.abs(mean - np.asarray(fedavg))
    assert np.all(err <= 4.5 * se + 2e-3), (err.max(), se.max())


def test_late_p_layers_mirrors_exact_p():
    """p_late^l is the exact probability that NO client is late at layer l
    — checked against a direct Monte-Carlo estimate."""
    U, L = 6, 4
    lam = jnp.asarray([2.0, 3.0, 5.0, 7.0, 4.0, 6.0], jnp.float32)
    p_late = np.asarray(late_p_layers(lam, L))
    n = 20000
    keys = jax.random.split(jax.random.PRNGKey(7), n)
    z = jax.vmap(lambda k: sample_depths(k, lam))(keys)     # (n, U)
    late = 1.0 - jax.vmap(lambda zz: contribution_mask(zz, L))(z)
    mc = np.asarray((late.sum(axis=1) == 0).mean(axis=0))   # (L,)
    np.testing.assert_allclose(p_late, mc, atol=0.02)
    # sanity: deeper layers are MORE often all-on-time?  no — layer l needs
    # depth >= L+1-l, so the layer-1 requirement is the harshest and being
    # late there is most likely: p_late increases with l
    assert np.all(np.diff(p_late) >= -1e-6)


def test_layer_preserved_when_empty():
    """ADEL-FL preserves layer params when no updates arrive (g~ = 0),
    unlike SALF's default FedAvg fallback."""
    U, L, F = 4, 3, 7
    g = jnp.ones((U, L, F))
    mask = jnp.ones((U, L)).at[:, 0].set(0.0)      # nobody reached layer 1
    p = jnp.asarray([0.9, 0.1, 0.0])
    agg = aggregate_grads({"w": g}, {"w": jnp.arange(L)}, mask, p)["w"]
    np.testing.assert_allclose(np.asarray(agg[0]), 0.0)     # preserved
    np.testing.assert_allclose(np.asarray(agg[1]),
                               1.0 / (1 - 0.1), rtol=1e-6)  # corrected
    np.testing.assert_allclose(np.asarray(agg[2]), 1.0, rtol=1e-6)
