"""Problem-2 solver: constraint satisfaction + improvement over the naive
constant allocation (both solver paths)."""
import numpy as np
import pytest

from repro.core.scheduler import (constant_schedule, solve_adam,
                                  solve_trust_region)
from repro.core.types import AnalysisConfig


@pytest.fixture(scope="module")
def cfg():
    return AnalysisConfig.default(U=10, L=8, R=12, T_max=120.0, seed=1)


def _check_feasible(s, cfg):
    assert s.T.shape == (cfg.R,)
    assert np.all(s.T > 0)
    assert s.T.sum() <= cfg.T_max * (1 + 1e-4)
    assert np.all(np.diff(s.T) <= 1e-5), "deadlines must be nonincreasing"
    assert np.all(s.p1 < 0.2 + 1e-6), "Lemma-3 validity p_t^1 < 0.2"
    assert s.m >= 1.0


def test_adam_solver_feasible_and_improves(cfg):
    base = constant_schedule(cfg)
    s = solve_adam(cfg, steps=1200)
    _check_feasible(s, cfg)
    assert s.objective <= base.objective * (1 + 1e-5), \
        (s.objective, base.objective)


def test_trust_region_solver_feasible_and_improves(cfg):
    base = constant_schedule(cfg)
    s = solve_trust_region(cfg, maxiter=150)
    _check_feasible(s, cfg)
    assert s.objective <= base.objective * (1 + 1e-4)


def test_deadlines_decrease_like_paper(cfg):
    """Fig. 2a/3a: the optimized allocation decreases over rounds (larger
    early deadlines exploit the larger early learning rates)."""
    s = solve_adam(cfg, steps=1200)
    assert s.T[0] > s.T[-1]


def test_batch_sizes_b3(cfg):
    s = solve_adam(cfg, steps=300)
    S = s.batch_sizes(cfg)
    assert S.shape == (cfg.R, cfg.U)
    assert np.all(S >= 1)
    # B3: S propto P_u for fixed round (up to the floor and B_u correction)
    fast, slow = np.argmax(cfg.P), np.argmin(cfg.P)
    assert S[0, fast] >= S[0, slow]
