"""The federated LM path on the unified round runtime.

* Golden-seed comparison: the ``RoundRuntime``-based ``run_training``
  matches the loss trajectory of the pre-refactor hand-rolled LM loop
  (reimplemented verbatim here from ``make_train_step``) on a reduced
  arch, evaluated on the SAME fixed pool-head rows.
* Backend equivalence: dense / chunked / shard_map / temporal produce the
  same LM trajectories.
* Donation safety: every backend really donates the params buffers (the
  input leaves are deleted after the round step on this jax/CPU build)
  and the full round loop — planning, width masks, eval, checkpoint hook
  — never touches a donated buffer.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.baselines import make_policy
from repro.core.scheduler import solve
from repro.core.types import AnalysisConfig
from repro.fl.backends import (BACKENDS, ExecSpec, ExecutionBackend,
                               make_backend)
from repro.fl.runtime import RoundRuntime, probe_s_max
from repro.fl.tasks import lm_task
from repro.launch.steps import make_train_step
from repro.launch.train import run_training
from repro.models import transformer as tr

ARCH = "qwen1.5-4b"
U, ROUNDS, TMAX, SEQ, ETA0, SEED = 4, 12, 60.0, 32, 1.0, 0


@pytest.fixture(scope="module")
def setup():
    cfg = get_config(ARCH).reduced()
    # n_eval=2*U -> the eval head is pool[:, :2], exactly the legacy
    # driver's eval rows
    task = lm_task(cfg, U=U, seq=SEQ, n_seq=96, n_eval=2 * U, seed=SEED)
    acfg = AnalysisConfig.default(U=U, L=task.model.L, R=ROUNDS, T_max=TMAX,
                                  eta0=ETA0, seed=SEED)
    schedule = solve(acfg, "adam", steps=600)
    return cfg, task, acfg, schedule


def _legacy_loop(cfg, task, acfg, schedule, eval_rows):
    """The pre-refactor launch/train.py round loop, verbatim semantics:
    fixed 4-sequence client minibatches drawn straight from the pool,
    ``make_train_step(mode="spatial")``, same policy plans."""
    client_batch = 4
    policy = make_policy("adel", acfg, schedule=schedule)
    key = jax.random.PRNGKey(SEED)
    key, k_init = jax.random.split(key)
    params = tr.init_params(k_init, cfg)
    pool = np.asarray(task.client_x)
    n_seq = pool.shape[1]
    step = jax.jit(make_train_step(cfg, U=U, mode="spatial", remat=False))
    eval_tok = jnp.asarray(eval_rows[:, :-1])
    eval_lab = jnp.asarray(eval_rows[:, 1:])
    eval_loss = jax.jit(lambda p: tr.loss_fn(p, cfg, eval_tok, eval_lab))
    eta, elapsed, losses = acfg.eta, 0.0, []
    for t in range(ROUNDS):
        key, k_round, k_batch = jax.random.split(key, 3)
        plan = policy.round(k_round, t)
        if elapsed + plan.elapsed > TMAX * (1 + 1e-6):
            break
        idx = np.asarray(jax.random.randint(
            k_batch, (U, client_batch), 0, n_seq))
        xb = np.stack([pool[u, idx[u]] for u in range(U)])
        tok = jnp.asarray(xb[:, :, :-1])
        lab = jnp.asarray(xb[:, :, 1:])
        params = step(params, tok, lab, plan.mask, plan.p,
                      jnp.float32(eta[t]))
        elapsed += plan.elapsed
        losses.append(float(eval_loss(params)))
    return losses


def _runtime_losses(task, acfg, schedule, backend="temporal", **kw):
    policy = make_policy("adel", acfg, schedule=schedule)
    s_max = max(min(probe_s_max(policy, ROUNDS), 32), 2)
    chunk = kw.pop("chunk_size", 2 if backend == "chunked" else None)
    runtime = RoundRuntime(task.model, policy, backend=backend,
                           chunk_size=chunk, **kw)
    _, hist = runtime.run(task.source(), rounds=ROUNDS, T_max=TMAX,
                          eta=acfg.eta, s_max=s_max,
                          key=jax.random.PRNGKey(SEED),
                          eval_fn=task.eval_fn(), eval_every=1)
    return hist


def test_matches_legacy_loop_golden_seed(setup):
    """Same arch, same schedule, same eval rows: the runtime-based driver
    tracks the old hand-rolled loop's loss trajectory (the minibatch
    sampler changed — plan-driven B3 batches instead of a fixed 4 — so
    the match is golden-seed tolerance, not bit-for-bit)."""
    cfg, task, acfg, schedule = setup
    legacy = _legacy_loop(cfg, task, acfg, schedule,
                          np.asarray(task.test_x))
    hist = _runtime_losses(task, acfg, schedule)
    new = hist.train_loss
    assert len(legacy) == len(new) == ROUNDS
    # both optimize: clear decrease from the same init
    assert legacy[-1] < legacy[0] - 0.05, legacy
    assert new[-1] < new[0] - 0.05, new
    # and land at the same level (golden-seed tolerance)
    assert abs(new[-1] - legacy[-1]) < 0.25, (new[-1], legacy[-1])
    # deterministic given the seed
    hist2 = _runtime_losses(task, acfg, schedule)
    np.testing.assert_allclose(new, hist2.train_loss, rtol=1e-6)


def test_lm_backend_equivalence(setup):
    """All four execution backends produce the same LM trajectory (up to
    float summation order) — the clock exactly, the losses tightly."""
    _, task, acfg, schedule = setup
    hists = {bk: _runtime_losses(task, acfg, schedule, backend=bk)
             for bk in BACKENDS}
    ref = hists["dense"]
    for bk in BACKENDS[1:]:
        h = hists[bk]
        assert h.rounds == ref.rounds
        np.testing.assert_allclose(h.times, ref.times, rtol=1e-6)
        np.testing.assert_allclose(h.train_loss, ref.train_loss,
                                   rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(h.accuracy, ref.accuracy, atol=5e-3)


class _DonationProbe(ExecutionBackend):
    """Wraps a backend and hard-deletes the input params buffers after
    each round step: ANY later read of a donated buffer then raises."""

    def __init__(self, inner):
        super().__init__(inner.model, donate=inner.donate)
        self.inner = inner
        self.name = inner.name
        self.deleted_by_donation = []

    def cohort_pad(self, U):
        return self.inner.cohort_pad(U)

    def describe(self):
        return self.inner.describe()

    def run_round(self, params, *args, **kwargs):
        out = self.inner.run_round(params, *args, **kwargs)
        leaves = jax.tree.leaves(params)
        self.deleted_by_donation.append(
            all(leaf.is_deleted() for leaf in leaves))
        for leaf in leaves:
            if not leaf.is_deleted():
                leaf.delete()
        return out


@pytest.mark.parametrize("pipeline", ["serial", "prefetch"])
@pytest.mark.parametrize("backend", BACKENDS)
def test_donation_safety(setup, backend, pipeline):
    """donate=True on every backend: the round step really consumes the
    params buffers, and nothing in the round loop (planning, eval,
    on_round hook) reads them afterwards. Under the prefetch pipeline the
    double-buffered stacked batches and the async eval readback must not
    resurrect a donated buffer either, and the AOT warm-up's dummy round
    donates its zero-params just like a real one."""
    _, task, acfg, schedule = setup
    policy = make_policy("adel", acfg, schedule=schedule)
    probe = _DonationProbe(make_backend(
        backend, task.model, donate=True,
        chunk_size=2 if backend == "chunked" else None))
    runtime = RoundRuntime(task.model, policy, backend=probe,
                           exec=ExecSpec(pipeline=pipeline))
    rounds = 4
    seen = []
    _, hist = runtime.run(task.source(), rounds=rounds, T_max=TMAX,
                          eta=acfg.eta, s_max=8,
                          key=jax.random.PRNGKey(SEED),
                          eval_fn=task.eval_fn(), eval_every=1,
                          on_round=lambda t, p, h: seen.append(t))
    assert len(hist.train_loss) == rounds
    assert seen == list(range(rounds))
    # donation is honored on this build: the step itself deleted the
    # incoming buffers (the probe found nothing left to delete); prefetch
    # adds the warm-up round's dummy params in front
    steps = rounds + (1 if pipeline == "prefetch" else 0)
    assert probe.deleted_by_donation == [True] * steps


def test_heterofl_width_masks_on_lm(setup):
    """HeteroFL width scaling runs on the transformer ModelAPI through the
    runtime (FFN-hidden-width masks), dense vs temporal equivalent."""
    _, task, acfg, schedule = setup
    hists = {}
    for bk in ("dense", "temporal"):
        policy = make_policy("heterofl", acfg)
        runtime = RoundRuntime(task.model, policy, backend=bk)
        _, hists[bk] = runtime.run(task.source(), rounds=4, T_max=TMAX,
                                   eta=acfg.eta, s_max=8,
                                   key=jax.random.PRNGKey(SEED),
                                   eval_fn=task.eval_fn(), eval_every=1)
    np.testing.assert_allclose(hists["dense"].train_loss,
                               hists["temporal"].train_loss,
                               rtol=2e-3, atol=2e-3)


def test_run_training_api_and_checkpoint(tmp_path):
    """The public driver: History-based output, replan hook, checkpoint
    via on_round."""
    ckpt = os.path.join(tmp_path, "ck")
    _, hist = run_training(ARCH, method="adel", rounds=4, tmax=20.0, U=3,
                           seq=16, n_seq=24, eta0=1.0, seed=1,
                           backend="temporal", replan="drift",
                           solver_steps=200, ckpt=ckpt, ckpt_every=2,
                           eval_every=1, verbose=False)
    assert len(hist.train_loss) == 4
    assert hist.method == "adel"
    assert os.path.exists(ckpt + ".npz") and os.path.exists(ckpt + ".json")
    # static population: drift never fires, but the hook path ran
    assert hist.replans == []
