"""End-to-end FL system behaviour on the paper-scale models: the ordering
claims (ADEL-FL beats SALF / Drop under a time budget) on synthetic data,
plus the big-arch federated driver."""
import jax
import numpy as np
import pytest

from repro.core.baselines import make_policy
from repro.core.scheduler import solve
from repro.core.types import AnalysisConfig
from repro.data.synthetic import make_image_dataset
from repro.fl.partition import dirichlet_partition, stack_clients
from repro.fl.server import run_federated
from repro.models.paper_models import make_mlp


@pytest.fixture(scope="module")
def mnist_setup():
    # mirrors the Fig.-2 benchmark regime (benchmarks/fig2_mnist.py)
    x_tr, y_tr, x_te, y_te = make_image_dataset(
        "mnist", n_train=2500, n_test=800, seed=0, noise_std=1.0)
    U = 10
    parts = dirichlet_partition(y_tr, U, alpha=0.5, seed=0)
    cx, cy, counts = stack_clients(x_tr, y_tr, parts)
    return U, cx, cy, counts, x_te, y_te


def _run(method, mnist_setup, R=25, tmax=None, seed=0):
    U, cx, cy, counts, x_te, y_te = mnist_setup
    model = make_mlp()
    # paper calibration: T_max/R such that avg backprop depth ~50% of layers
    # (Section IV-A) — the tight-budget regime where adaptivity matters.
    # eta0=2.0 -> eta_1 = 1.0 under the inverse decay; the tiny MLP is fine.
    tmax = R * model.L * 0.5 if tmax is None else tmax
    cfg = AnalysisConfig.default(U=U, L=model.L, R=R, T_max=tmax,
                                 eta0=2.0, seed=0)
    schedule = solve(cfg, "adam", steps=800) if method == "adel" else None
    policy = make_policy(method, cfg, schedule=schedule)
    _, hist = run_federated(
        model, policy, cfg,
        jax.numpy.asarray(cx), jax.numpy.asarray(cy),
        jax.numpy.asarray(counts), jax.numpy.asarray(x_te),
        jax.numpy.asarray(y_te), key=jax.random.PRNGKey(seed),
        eval_every=5)
    return hist


def test_adel_runs_and_learns(mnist_setup):
    hist = _run("adel", mnist_setup)
    assert len(hist.accuracy) >= 3
    assert hist.accuracy[-1] > 0.3, hist.accuracy   # well above 10% chance
    # R2: simulated clock within budget (T_max = R * L * 0.5 = 37.5)
    assert hist.times[-1] <= 37.5 * 1.001


def test_adel_beats_drop_stragglers(mnist_setup):
    """The paper's central experimental claim, on synthetic data."""
    acc_adel = _run("adel", mnist_setup).accuracy[-1]
    acc_drop = _run("drop", mnist_setup).accuracy[-1]
    assert acc_adel > acc_drop, (acc_adel, acc_drop)


def test_adel_at_least_matches_salf(mnist_setup):
    # R=40 as in the paper's Fig.-2 regime (at very small R the two methods
    # are within noise of each other; the gap grows with rounds)
    acc_adel = np.mean(_run("adel", mnist_setup, R=40).accuracy[-2:])
    acc_salf = np.mean(_run("salf", mnist_setup, R=40).accuracy[-2:])
    assert acc_adel >= acc_salf - 0.02, (acc_adel, acc_salf)


def test_wait_fits_fewer_rounds(mnist_setup):
    """Wait-Stragglers burns the clock on slow devices -> fewer rounds."""
    h_wait = _run("wait", mnist_setup)
    h_adel = _run("adel", mnist_setup)
    assert h_wait.rounds[-1] < h_adel.rounds[-1]


def test_big_arch_federated_training_loss_drops():
    """launch.train on a reduced assigned arch: loss decreases (now on
    RoundRuntime — the temporal grad-accumulation backend)."""
    from repro.launch.train import run_training
    _, hist = run_training("qwen1.5-4b", method="adel", rounds=12, tmax=60.0,
                           U=4, seq=32, eta0=1.0, solver="adam",
                           backend="temporal", verbose=False)
    assert hist.train_loss[-1] < hist.train_loss[0], hist.train_loss
