"""System-level behaviour: checkpointing round-trip, optimizer, data
pipeline, partitioning, and the property-based straggler invariants."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.core.straggler import batch_sizes, contribution_mask, poisson_rates
from repro.data.synthetic import make_image_dataset, make_lm_dataset
from repro.fl.partition import dirichlet_partition, iid_partition, stack_clients
from repro.optim import inverse_decay, momentum, sgd


def test_checkpoint_roundtrip():
    params = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
              "b": [jnp.ones((4,)), jnp.zeros((2, 2), jnp.int32)]}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt_1")
        save_checkpoint(path, params, step=7, meta={"arch": "test"})
        restored, manifest = load_checkpoint(path, params)
        assert manifest["step"] == 7
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_sgd_and_momentum_step():
    params = {"w": jnp.ones((3,))}
    grads = {"w": jnp.full((3,), 2.0)}
    opt = sgd()
    new, _ = opt.update(grads, opt.init(params), params, jnp.float32(0.1))
    np.testing.assert_allclose(np.asarray(new["w"]), 0.8, rtol=1e-6)
    mopt = momentum(0.5)
    st8 = mopt.init(params)
    p1, st8 = mopt.update(grads, st8, params, jnp.float32(0.1))
    p2, _ = mopt.update(grads, st8, p1, jnp.float32(0.1))
    # second step includes 0.5 * previous velocity
    np.testing.assert_allclose(np.asarray(p2["w"]), 1 - 0.2 - 0.3, rtol=1e-5)


def test_inverse_decay_satisfies_theorem_condition():
    eta = inverse_decay(0.5, 50)
    assert np.all(eta[:-1] <= 2 * eta[1:] + 1e-9)   # eta_t <= 2 eta_{t+1}
    assert np.all(np.diff(eta) < 0)


def test_image_dataset_learnable_signal():
    x, y, xt, yt = make_image_dataset("mnist", n_train=500, n_test=100,
                                      seed=0, noise_std=0.5)
    assert x.shape == (500, 28, 28, 1) and y.shape == (500,)
    # nearest-template classification works at low noise -> classes differ
    assert len(np.unique(y)) == 10


def test_lm_dataset_structure():
    toks = make_lm_dataset(vocab=256, n_tokens=4096, seed=0)
    assert toks.shape == (4096,) and toks.max() < 256 and toks.min() >= 0


def test_dirichlet_partition_covers_everything():
    y = np.random.default_rng(0).integers(0, 10, 2000)
    parts = dirichlet_partition(y, U=8, alpha=0.5, seed=1)
    all_idx = np.concatenate(parts)
    assert len(all_idx) == 2000
    assert len(np.unique(all_idx)) == 2000          # a true partition
    assert min(len(p) for p in parts) >= 2


def test_stack_clients_padding():
    x = np.arange(10, dtype=np.float32).reshape(10, 1)
    y = np.arange(10)
    parts = iid_partition(10, 3, seed=0)
    xs, ys, counts = stack_clients(x, y, parts)
    assert xs.shape[0] == 3 and xs.shape[1] == max(len(p) for p in parts)
    assert counts.sum() == 10


@settings(deadline=None, max_examples=50)
@given(st.floats(0.5, 100.0), st.floats(1.0, 50.0),
       st.floats(0.1, 10.0), st.floats(0.0, 0.4))
def test_b3_batch_sizes_properties(T_d, m, P, Bfrac):
    """B3 invariants: S >= 1; S grows with m and with P."""
    B = jnp.float32(Bfrac * T_d)
    s = float(batch_sizes(T_d, m, jnp.float32(P), B))
    assert s >= 1.0
    s2 = float(batch_sizes(T_d, 2 * m, jnp.float32(P), B))
    assert s2 >= s - 1e-6


@settings(deadline=None, max_examples=50)
@given(st.integers(1, 40), st.integers(0, 60))
def test_contribution_mask_is_suffix(L, z):
    """A client contributes a SUFFIX of layers (backprop reaches the output
    side first): mask rows are nondecreasing in l."""
    mask = np.asarray(contribution_mask(jnp.asarray([z]), L))[0]
    assert mask.shape == (L,)
    assert np.all(np.diff(mask) >= 0)
    assert mask.sum() == min(z, L)


@settings(deadline=None, max_examples=30)
@given(st.floats(1.0, 60.0), st.floats(1.0, 20.0))
def test_poisson_rate_lower_bound(T_d, m):
    """Appendix A: lambda_u >= T/m for every user (basis of Lemma 1).

    Holds in the feasible regime m P_u (T - B_u)/T >= 1 (i.e. S_u >= 1
    before clipping) — the same condition Problem 2 enforces so the B_t
    denominator stays positive.
    """
    P = jnp.asarray([0.5, 1.0, 3.0])
    B = jnp.zeros((3,))
    feasible = np.asarray(m * np.asarray(P) >= 1.0)
    lam = np.asarray(poisson_rates(T_d, m, P, B))
    assert np.all(lam[feasible] >= T_d / m - 1e-4)
