"""Execution-backend equivalence: dense / chunked / shard_map / temporal /
buffered(lam=0) produce the same History trajectories (up to float
summation order) for ADEL and SALF, HeteroFL width masks flow through
every backend, and the ``ExecSpec`` surface resolves identically to the
legacy kwargs.

The multi-device shard_map case needs ``XLA_FLAGS=
--xla_force_host_platform_device_count=N`` set BEFORE jax initializes, so it
runs in a subprocess (>= 4 host devices, per the acceptance criteria)."""
import argparse
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.baselines import make_policy
from repro.core.scheduler import solve
from repro.core.types import AnalysisConfig
from repro.data.synthetic import make_image_dataset
from repro.fl.backends import (BACKENDS, BufferedBackend, ChunkedBackend,
                               DenseBackend, ExecSpec, ShardMapBackend,
                               TemporalBackend, make_backend)
from repro.fl.partition import dirichlet_partition, stack_clients
from repro.fl.server import run_federated
from repro.models.paper_models import make_mlp

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

R = 5
U = 8


@pytest.fixture(scope="module")
def setup():
    x_tr, y_tr, x_te, y_te = make_image_dataset(
        "mnist", n_train=600, n_test=200, seed=0, noise_std=1.0)
    parts = dirichlet_partition(y_tr, U, alpha=0.5, seed=0)
    cx, cy, counts = stack_clients(x_tr, y_tr, parts)
    model = make_mlp()
    cfg = AnalysisConfig.default(U=U, L=model.L, R=R, T_max=R * model.L * 0.5,
                                 eta0=2.0, seed=0)
    data = (jnp.asarray(cx), jnp.asarray(cy), jnp.asarray(counts),
            jnp.asarray(x_te), jnp.asarray(y_te))
    schedule = solve(cfg, "adam", steps=150)
    return model, cfg, data, schedule


def _run(setup, method, backend, chunk_size=None, **kw):
    model, cfg, data, schedule = setup
    policy = make_policy(method, cfg,
                         schedule=schedule if method == "adel" else None)
    # chunk_size only applies to the chunked backend; passing it elsewhere
    # now (correctly) warns through ExecSpec.resolve
    if chunk_size is None and backend == "chunked":
        chunk_size = 3
    _, hist = run_federated(model, policy, cfg, *data,
                            key=jax.random.PRNGKey(0), backend=backend,
                            chunk_size=chunk_size, **kw)
    return hist


def _assert_equivalent(a, b):
    # the simulated clock and plans are backend-independent — exact
    assert a.rounds == b.rounds
    np.testing.assert_allclose(a.deadlines, b.deadlines, rtol=1e-6)
    np.testing.assert_allclose(a.times, b.times, rtol=1e-6)
    # learning trajectories agree up to float summation order
    np.testing.assert_allclose(a.accuracy, b.accuracy, atol=0.015)
    np.testing.assert_allclose(a.train_loss, b.train_loss, rtol=0.02,
                               atol=0.02)


@pytest.mark.parametrize("method", ["adel", "salf"])
def test_dense_vs_chunked(setup, method):
    """chunk_size=3 pads the 8-client cohort to 9 and runs 3 chunks."""
    _assert_equivalent(_run(setup, method, "dense"),
                       _run(setup, method, "chunked"))


@pytest.mark.parametrize("method", ["adel", "salf"])
def test_dense_vs_shard_map_single_device(setup, method):
    """1 host device -> 1 shard holding the whole cohort; psum over a
    singleton axis must reproduce the dense aggregation."""
    _assert_equivalent(_run(setup, method, "dense"),
                       _run(setup, method, "shard_map"))


@pytest.mark.parametrize("method", ["adel", "salf"])
def test_dense_vs_temporal(setup, method):
    """The grad-accumulation scan (Eq. 5 coefficient fold) reproduces the
    vmapped dense aggregation."""
    _assert_equivalent(_run(setup, method, "dense"),
                       _run(setup, method, "temporal"))


def test_heterofl_same_on_all_backends(setup):
    hists = [_run(setup, "heterofl", bk) for bk in BACKENDS]
    for h in hists[1:]:
        _assert_equivalent(hists[0], h)


def test_single_chunk_falls_through_to_dense(setup):
    """chunk_size >= cohort: the chunked backend reuses the dense step."""
    _assert_equivalent(_run(setup, "salf", "dense"),
                       _run(setup, "salf", "chunked", chunk_size=U))


def test_backend_registry_and_padding():
    model = make_mlp()
    assert make_backend("dense", model).cohort_pad(10) == 10
    chunked = make_backend("chunked", model, chunk_size=8)
    assert chunked.cohort_pad(10) == 16
    assert chunked.cohort_pad(8) == 8      # single chunk, no dead padding
    assert chunked.cohort_pad(4) == 4      # chunk clipped to the cohort
    assert make_backend("temporal", model).cohort_pad(10) == 10
    for name, cls in [("dense", DenseBackend), ("chunked", ChunkedBackend),
                      ("shard_map", ShardMapBackend),
                      ("temporal", TemporalBackend)]:
        assert isinstance(make_backend(name, model), cls)
    bk = DenseBackend(model)
    assert make_backend(bk, model) is bk
    with pytest.raises(ValueError):
        make_backend("nope", model)


# ---------------------------------------------------------------------------
# compressed wire payloads (repro.core.compression)
# ---------------------------------------------------------------------------

# stated drift tolerance for compressed-vs-dense trajectories: int8
# symmetric quantization perturbs each aggregated delta element by at most
# amax/254 per contributor, which over R=5 rounds must not move final
# accuracy by more than the ISSUE's acceptance bound
COMPRESSED_ACC_TOL = 0.02


@pytest.mark.parametrize("backend", list(BACKENDS))
def test_int8_compressed_drift_all_backends(setup, backend):
    """int8-compressed trajectories on every backend stay within the
    stated tolerance of the uncompressed dense run; plans and the
    simulated clock are untouched by compression."""
    base = _run(setup, "adel", "dense")
    comp = _run(setup, "adel", backend, compression="int8")
    assert comp.rounds == base.rounds
    np.testing.assert_allclose(comp.deadlines, base.deadlines, rtol=1e-6)
    np.testing.assert_allclose(comp.times, base.times, rtol=1e-6)
    np.testing.assert_allclose(comp.accuracy, base.accuracy,
                               atol=COMPRESSED_ACC_TOL)
    assert abs(comp.accuracy[-1] - base.accuracy[-1]) <= COMPRESSED_ACC_TOL


def test_compressed_backends_agree(setup):
    """The SAME deterministic quantization runs everywhere, so compressed
    backends agree with compressed dense to the usual summation-order
    tolerance."""
    ref = _run(setup, "adel", "dense", compression="int8")
    for backend in ("chunked", "shard_map", "temporal"):
        _assert_equivalent(ref, _run(setup, "adel", backend,
                                     compression="int8"))


def test_topk8_compressed_converges(setup):
    """Top-k sparsification at a generous kept fraction still tracks the
    dense run within the stated tolerance."""
    base = _run(setup, "adel", "dense")
    comp = _run(setup, "adel", "dense", compression=("topk8", 0.5))
    np.testing.assert_allclose(comp.times, base.times, rtol=1e-6)
    assert abs(comp.accuracy[-1] - base.accuracy[-1]) <= COMPRESSED_ACC_TOL


@pytest.mark.parametrize("backend", ["dense", "temporal"])
def test_agg_impl_pallas_matches_jnp(setup, backend):
    """agg_impl="pallas" routes Eq. 5 through the fused kernels (interpret
    mode on CPU) and must reproduce the jnp fold."""
    _assert_equivalent(_run(setup, "adel", backend),
                       _run(setup, "adel", backend, agg_impl="pallas"))


def test_pallas_agg_with_compression(setup):
    """Compression + the fused adel_agg_q8 kernel together."""
    _assert_equivalent(
        _run(setup, "adel", "dense", compression="int8"),
        _run(setup, "adel", "dense", compression="int8",
             agg_impl="pallas"))


def test_heterofl_rejects_compression(setup):
    """HeteroFL's width-overlap mean has no sound dequant-weight: every
    backend must refuse the combination up front."""
    for backend in BACKENDS:
        with pytest.raises(ValueError, match="HeteroFL"):
            _run(setup, "heterofl", backend, compression="int8")


def test_describe_reports_compression_and_agg_impl():
    model = make_mlp()
    d = make_backend("dense", model, compression="int8",
                     agg_impl="pallas").describe()
    assert d["compression"] == "int8" and d["agg_impl"] == "pallas"
    d = make_backend("chunked", model).describe()
    assert d["compression"] == "none" and d["agg_impl"] == "jnp"


def test_compressed_byte_counters(setup):
    """All four backends record the split logical/wire counters, with the
    same deterministic totals (chunked counts per padded chunk)."""
    from repro import obs
    model, cfg, data, schedule = setup
    totals = {}
    for backend in BACKENDS:
        sink = obs.MemorySink()
        policy = make_policy("adel", cfg, schedule=schedule)
        run_federated(model, policy, cfg, *data, key=jax.random.PRNGKey(0),
                      backend=backend,
                      chunk_size=3 if backend == "chunked" else None,
                      compression="int8", tracer=obs.Tracer(sink))
        ctr = {}
        for r in sink.records:
            if r.get("kind") == "count" and "bytes" in r.get("name", ""):
                ctr[r["name"]] = ctr.get(r["name"], 0) + r["value"]
        assert ctr["aggregate_bytes_logical"] > 0
        assert ctr["aggregate_bytes_wire"] > 0
        ratio = ctr["aggregate_bytes_logical"] / ctr["aggregate_bytes_wire"]
        assert ratio > 3.5, (backend, ctr)
        totals[backend] = ctr
    # dense / shard_map (1 host device) / temporal count the same padded
    # cohort; chunked pads 8 clients to 3 chunks of 3
    assert totals["dense"] == totals["temporal"]


# ---------------------------------------------------------------------------
# ExecSpec: one execution surface for every entry point
# ---------------------------------------------------------------------------


def _assert_bit_identical(a, b):
    assert a.rounds == b.rounds
    np.testing.assert_array_equal(np.asarray(a.deadlines),
                                  np.asarray(b.deadlines))
    np.testing.assert_array_equal(np.asarray(a.times), np.asarray(b.times))
    np.testing.assert_array_equal(np.asarray(a.accuracy),
                                  np.asarray(b.accuracy))
    np.testing.assert_array_equal(np.asarray(a.train_loss),
                                  np.asarray(b.train_loss))


def test_execspec_roundtrip_and_resolve():
    spec = ExecSpec(backend="chunked", chunk_size=4, compression="int8",
                    agg_impl="pallas")
    # the legacy compression spec forms normalize on construction
    assert spec.compression.mode == "int8"
    d = spec.as_dict()
    assert d["backend"] == "chunked" and d["compression"]["mode"] == "int8"
    # legacy kwargs overlay through THE parsing path; None means "keep"
    r = ExecSpec.resolve(spec, agg_impl="jnp")
    assert r.agg_impl == "jnp" and r.chunk_size == 4
    assert ExecSpec.resolve(spec) == spec
    with pytest.raises(TypeError, match="unknown execution kwargs"):
        ExecSpec.resolve(spec, not_a_knob=1)
    with pytest.raises(ValueError, match="unknown backend"):
        ExecSpec(backend="nope")
    with pytest.raises(ValueError):
        ExecSpec(lam=1.5)


def test_execspec_warns_on_ignored_knobs():
    with pytest.warns(UserWarning, match="chunk_size"):
        ExecSpec.resolve(backend="dense", chunk_size=4)
    with pytest.warns(UserWarning, match="staleness"):
        ExecSpec.resolve(backend="dense", lam=0.5)
    with pytest.raises(ValueError, match="mesh"):
        ExecSpec.resolve(backend="dense", mesh=object(), strict=True)


def test_execspec_strict_env(monkeypatch):
    monkeypatch.setenv("REPRO_EXEC_STRICT", "1")
    with pytest.raises(ValueError, match="chunk_size"):
        ExecSpec.resolve(backend="temporal", chunk_size=4)


def test_execspec_cli_roundtrip():
    ap = argparse.ArgumentParser()
    ExecSpec.add_cli_args(ap)
    args = ap.parse_args(["--backend", "buffered", "--lam", "0.3",
                          "--compression", "int8"])
    spec = ExecSpec.from_cli(args)
    assert spec.backend == "buffered" and spec.lam == 0.3
    assert spec.compression.mode == "int8"
    # no flags -> the front-end's base spec rides through unchanged
    assert ExecSpec.from_cli(ap.parse_args([]),
                             base=ExecSpec(backend="chunked",
                                           chunk_size=4)) == \
        ExecSpec(backend="chunked", chunk_size=4)


def test_make_backend_accepts_spec_and_legacy():
    model = make_mlp()
    spec = ExecSpec(backend="chunked", chunk_size=8)
    a = make_backend(exec=spec, model=model)
    b = make_backend("chunked", model, chunk_size=8)
    assert type(a) is type(b) is ChunkedBackend
    assert a.chunk_size == b.chunk_size == 8
    # an ExecSpec in the positional selector slot works too
    c = make_backend(spec, model)
    assert isinstance(c, ChunkedBackend) and c.chunk_size == 8
    buf = make_backend("buffered", model, lam=0.25, max_age=2)
    assert isinstance(buf, BufferedBackend)
    assert buf.lam == 0.25 and buf.max_age == 2
    assert not buf.needs_ctx ^ (buf.lam > 0)


@pytest.mark.parametrize("backend", list(BACKENDS))
def test_execspec_equals_legacy_kwargs(setup, backend):
    """run_federated(backend=...) and run_federated(exec=ExecSpec(...))
    must produce bit-identical Histories on every backend."""
    model, cfg, data, schedule = setup
    kw = {"chunk_size": 3} if backend == "chunked" else {}
    legacy = _run(setup, "adel", backend, **kw)
    policy = make_policy("adel", cfg, schedule=schedule)
    _, spec_hist = run_federated(model, policy, cfg, *data,
                                 key=jax.random.PRNGKey(0),
                                 exec=ExecSpec(backend=backend, **kw))
    _assert_bit_identical(legacy, spec_hist)


# ---------------------------------------------------------------------------
# buffered (semi-async) backend: staleness-weighted delayed gradients
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", ["adel", "salf"])
def test_buffered_lam0_bit_identical_to_dense(setup, method):
    """lam=0 is exact round-synchronous semantics: the buffered backend
    delegates every round to the inherited dense step, bit for bit."""
    _assert_bit_identical(_run(setup, method, "dense"),
                          _run(setup, method, "buffered"))


def _run_buffered(setup, method="adel", lam=0.6, tracer=None, backend=None,
                  **spec_kw):
    model, cfg, data, schedule = setup
    policy = make_policy(method, cfg,
                         schedule=schedule if method == "adel" else None)
    exec_spec = (None if backend is not None
                 else ExecSpec(backend="buffered", lam=lam, **spec_kw))
    return run_federated(model, policy, cfg, *data,
                         key=jax.random.PRNGKey(0), exec=exec_spec,
                         backend=backend, tracer=tracer)


def test_buffered_carries_late_work(setup):
    """lam>0 banks stragglers' unfinished layers and folds them into later
    rounds; the ledger rows carry the carried_in/out/stale columns and the
    drift summary aggregates them."""
    from repro import obs
    from repro.obs.ledger import drift_summary, ledger_rows
    sink = obs.MemorySink()
    _, hist = _run_buffered(setup, tracer=obs.Tracer(sink))
    rows = ledger_rows(sink.records)
    assert rows
    assert any(r.get("carried_in", 0) > 0 for r in rows), rows
    assert any(r.get("carried_out", 0) > 0 for r in rows)
    # staleness of every fold is >= 1 round (work banked at round t is
    # never folded before round t+1)
    taus = {int(tau) for r in rows for tau in (r.get("stale") or {})}
    assert taus and min(taus) >= 1
    drift = drift_summary(rows)
    assert drift.get("carried_in_total", 0) > 0
    assert drift.get("stale_mean", 0.0) >= 1.0
    assert np.isfinite(hist.accuracy[-1])


def test_buffered_int8_banks_wire_format(setup):
    """Under compression the carry buffer stores the int8 WIRE tuples the
    on-time reduction consumed — never re-materialized dense float32."""
    bk = make_backend("buffered", make_mlp(), lam=0.6, compression="int8")
    _, hist = _run_buffered(setup, backend=bk)
    assert bk.last_carry["carried_in"] > 0 or bk.last_carry["carried_out"] > 0
    assert bk._slots, "expected banked late work in the carry ring"
    q, scale = bk._slots[-1]["banked"][0][:2]
    assert q.dtype == jnp.int8 and scale.dtype == jnp.float32
    assert np.isfinite(hist.accuracy[-1])


def test_buffered_heterofl_lam_positive_rejected(setup):
    with pytest.raises(ValueError, match="HeteroFL"):
        _run_buffered(setup, method="heterofl")


def test_buffered_reset_state_between_runs(setup):
    """A backend instance reused across runs must not leak carry slots."""
    bk = make_backend("buffered", make_mlp(), lam=0.6)
    _run_buffered(setup, backend=bk)
    assert bk._slots
    bk.reset_state()
    assert not bk._slots and not bk.last_carry


_MULTIDEV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8")
    import jax
    import jax.numpy as jnp
    import numpy as np
    assert len(jax.devices()) >= 4, jax.devices()

    from repro.core.baselines import make_policy
    from repro.core.scheduler import solve
    from repro.core.types import AnalysisConfig
    from repro.data.synthetic import make_image_dataset
    from repro.fl.partition import dirichlet_partition, stack_clients
    from repro.fl.server import run_federated
    from repro.models.paper_models import make_mlp

    x_tr, y_tr, x_te, y_te = make_image_dataset(
        "mnist", n_train=600, n_test=200, seed=0, noise_std=1.0)
    U, R = 8, 5
    parts = dirichlet_partition(y_tr, U, alpha=0.5, seed=0)
    cx, cy, counts = stack_clients(x_tr, y_tr, parts)
    model = make_mlp()
    cfg = AnalysisConfig.default(U=U, L=model.L, R=R, T_max=R * model.L * 0.5,
                                 eta0=2.0, seed=0)
    data = (jnp.asarray(cx), jnp.asarray(cy), jnp.asarray(counts),
            jnp.asarray(x_te), jnp.asarray(y_te))
    schedule = solve(cfg, "adam", steps=150)

    from repro.fl.backends import make_backend
    bk = make_backend("shard_map", model)
    assert bk.n_shards >= 4, bk.describe()
    assert bk.cohort_pad(U) == U  # 8 clients over 8 shards

    for method in ("adel", "salf"):
        hists = {}
        for backend in ("dense", "shard_map"):
            policy = make_policy(
                method, cfg, schedule=schedule if method == "adel" else None)
            _, hists[backend] = run_federated(
                model, policy, cfg, *data, key=jax.random.PRNGKey(0),
                backend=backend)
        a, b = hists["dense"], hists["shard_map"]
        assert a.rounds == b.rounds
        np.testing.assert_allclose(a.times, b.times, rtol=1e-6)
        np.testing.assert_allclose(a.accuracy, b.accuracy, atol=0.015)
        np.testing.assert_allclose(a.train_loss, b.train_loss, rtol=0.02,
                                   atol=0.02)
        print(method, "ok:", [round(x, 4) for x in b.accuracy])
    print("MULTIDEV_OK")
""")


def test_shard_map_multi_device_subprocess():
    """shard_map over >= 4 forced host devices matches dense, adel + salf."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _MULTIDEV_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0 and "MULTIDEV_OK" in proc.stdout, (
        proc.stdout + "\n" + proc.stderr)
