"""Execution-backend equivalence: dense / chunked / shard_map / temporal
produce the same History trajectories (up to float summation order) for
ADEL and SALF, and HeteroFL width masks flow through every backend.

The multi-device shard_map case needs ``XLA_FLAGS=
--xla_force_host_platform_device_count=N`` set BEFORE jax initializes, so it
runs in a subprocess (>= 4 host devices, per the acceptance criteria)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.baselines import make_policy
from repro.core.scheduler import solve
from repro.core.types import AnalysisConfig
from repro.data.synthetic import make_image_dataset
from repro.fl.backends import (BACKENDS, ChunkedBackend, DenseBackend,
                               ShardMapBackend, TemporalBackend,
                               make_backend)
from repro.fl.partition import dirichlet_partition, stack_clients
from repro.fl.server import run_federated
from repro.models.paper_models import make_mlp

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

R = 5
U = 8


@pytest.fixture(scope="module")
def setup():
    x_tr, y_tr, x_te, y_te = make_image_dataset(
        "mnist", n_train=600, n_test=200, seed=0, noise_std=1.0)
    parts = dirichlet_partition(y_tr, U, alpha=0.5, seed=0)
    cx, cy, counts = stack_clients(x_tr, y_tr, parts)
    model = make_mlp()
    cfg = AnalysisConfig.default(U=U, L=model.L, R=R, T_max=R * model.L * 0.5,
                                 eta0=2.0, seed=0)
    data = (jnp.asarray(cx), jnp.asarray(cy), jnp.asarray(counts),
            jnp.asarray(x_te), jnp.asarray(y_te))
    schedule = solve(cfg, "adam", steps=150)
    return model, cfg, data, schedule


def _run(setup, method, backend, chunk_size=3):
    model, cfg, data, schedule = setup
    policy = make_policy(method, cfg,
                         schedule=schedule if method == "adel" else None)
    _, hist = run_federated(model, policy, cfg, *data,
                            key=jax.random.PRNGKey(0), backend=backend,
                            chunk_size=chunk_size)
    return hist


def _assert_equivalent(a, b):
    # the simulated clock and plans are backend-independent — exact
    assert a.rounds == b.rounds
    np.testing.assert_allclose(a.deadlines, b.deadlines, rtol=1e-6)
    np.testing.assert_allclose(a.times, b.times, rtol=1e-6)
    # learning trajectories agree up to float summation order
    np.testing.assert_allclose(a.accuracy, b.accuracy, atol=0.015)
    np.testing.assert_allclose(a.train_loss, b.train_loss, rtol=0.02,
                               atol=0.02)


@pytest.mark.parametrize("method", ["adel", "salf"])
def test_dense_vs_chunked(setup, method):
    """chunk_size=3 pads the 8-client cohort to 9 and runs 3 chunks."""
    _assert_equivalent(_run(setup, method, "dense"),
                       _run(setup, method, "chunked"))


@pytest.mark.parametrize("method", ["adel", "salf"])
def test_dense_vs_shard_map_single_device(setup, method):
    """1 host device -> 1 shard holding the whole cohort; psum over a
    singleton axis must reproduce the dense aggregation."""
    _assert_equivalent(_run(setup, method, "dense"),
                       _run(setup, method, "shard_map"))


@pytest.mark.parametrize("method", ["adel", "salf"])
def test_dense_vs_temporal(setup, method):
    """The grad-accumulation scan (Eq. 5 coefficient fold) reproduces the
    vmapped dense aggregation."""
    _assert_equivalent(_run(setup, method, "dense"),
                       _run(setup, method, "temporal"))


def test_heterofl_same_on_all_backends(setup):
    hists = [_run(setup, "heterofl", bk) for bk in BACKENDS]
    for h in hists[1:]:
        _assert_equivalent(hists[0], h)


def test_single_chunk_falls_through_to_dense(setup):
    """chunk_size >= cohort: the chunked backend reuses the dense step."""
    _assert_equivalent(_run(setup, "salf", "dense"),
                       _run(setup, "salf", "chunked", chunk_size=U))


def test_backend_registry_and_padding():
    model = make_mlp()
    assert make_backend("dense", model).cohort_pad(10) == 10
    chunked = make_backend("chunked", model, chunk_size=8)
    assert chunked.cohort_pad(10) == 16
    assert chunked.cohort_pad(8) == 8      # single chunk, no dead padding
    assert chunked.cohort_pad(4) == 4      # chunk clipped to the cohort
    assert make_backend("temporal", model).cohort_pad(10) == 10
    for name, cls in [("dense", DenseBackend), ("chunked", ChunkedBackend),
                      ("shard_map", ShardMapBackend),
                      ("temporal", TemporalBackend)]:
        assert isinstance(make_backend(name, model), cls)
    bk = DenseBackend(model)
    assert make_backend(bk, model) is bk
    with pytest.raises(ValueError):
        make_backend("nope", model)


_MULTIDEV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8")
    import jax
    import jax.numpy as jnp
    import numpy as np
    assert len(jax.devices()) >= 4, jax.devices()

    from repro.core.baselines import make_policy
    from repro.core.scheduler import solve
    from repro.core.types import AnalysisConfig
    from repro.data.synthetic import make_image_dataset
    from repro.fl.partition import dirichlet_partition, stack_clients
    from repro.fl.server import run_federated
    from repro.models.paper_models import make_mlp

    x_tr, y_tr, x_te, y_te = make_image_dataset(
        "mnist", n_train=600, n_test=200, seed=0, noise_std=1.0)
    U, R = 8, 5
    parts = dirichlet_partition(y_tr, U, alpha=0.5, seed=0)
    cx, cy, counts = stack_clients(x_tr, y_tr, parts)
    model = make_mlp()
    cfg = AnalysisConfig.default(U=U, L=model.L, R=R, T_max=R * model.L * 0.5,
                                 eta0=2.0, seed=0)
    data = (jnp.asarray(cx), jnp.asarray(cy), jnp.asarray(counts),
            jnp.asarray(x_te), jnp.asarray(y_te))
    schedule = solve(cfg, "adam", steps=150)

    from repro.fl.backends import make_backend
    bk = make_backend("shard_map", model)
    assert bk.n_shards >= 4, bk.describe()
    assert bk.cohort_pad(U) == U  # 8 clients over 8 shards

    for method in ("adel", "salf"):
        hists = {}
        for backend in ("dense", "shard_map"):
            policy = make_policy(
                method, cfg, schedule=schedule if method == "adel" else None)
            _, hists[backend] = run_federated(
                model, policy, cfg, *data, key=jax.random.PRNGKey(0),
                backend=backend)
        a, b = hists["dense"], hists["shard_map"]
        assert a.rounds == b.rounds
        np.testing.assert_allclose(a.times, b.times, rtol=1e-6)
        np.testing.assert_allclose(a.accuracy, b.accuracy, atol=0.015)
        np.testing.assert_allclose(a.train_loss, b.train_loss, rtol=0.02,
                                   atol=0.02)
        print(method, "ok:", [round(x, 4) for x in b.accuracy])
    print("MULTIDEV_OK")
""")


def test_shard_map_multi_device_subprocess():
    """shard_map over >= 4 forced host devices matches dense, adel + salf."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _MULTIDEV_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0 and "MULTIDEV_OK" in proc.stdout, (
        proc.stdout + "\n" + proc.stderr)
