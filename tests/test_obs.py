"""Telemetry (repro.obs): span invariants, NullTracer overhead budget,
tracing-on == tracing-off trajectories on every backend, the History
round-trip fix, the clock-model ledger math, and the timeline renderer."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core.baselines import make_policy
from repro.core.replan import ReplanEvent
from repro.core.scheduler import solve
from repro.core.types import AnalysisConfig
from repro.data.synthetic import make_image_dataset
from repro.fl.partition import dirichlet_partition, stack_clients
from repro.fl.runtime import History
from repro.fl.server import run_federated
from repro.models.paper_models import make_mlp
from repro.obs.ledger import drift_summary, expected_depth, phase_table
from repro.obs.timeline import load_events, render

R = 4
U = 6


@pytest.fixture(scope="module")
def setup():
    x_tr, y_tr, x_te, y_te = make_image_dataset(
        "mnist", n_train=400, n_test=120, seed=0, noise_std=1.0)
    parts = dirichlet_partition(y_tr, U, alpha=0.5, seed=0)
    cx, cy, counts = stack_clients(x_tr, y_tr, parts)
    model = make_mlp()
    cfg = AnalysisConfig.default(U=U, L=model.L, R=R, T_max=R * model.L * 0.5,
                                 eta0=2.0, seed=0)
    data = (jnp.asarray(cx), jnp.asarray(cy), jnp.asarray(counts),
            jnp.asarray(x_te), jnp.asarray(y_te))
    schedule = solve(cfg, "adam", steps=60)
    return model, cfg, data, schedule


def _run(setup, backend, tracer=None, chunk_size=None):
    model, cfg, data, schedule = setup
    policy = make_policy("adel", cfg, schedule=schedule)
    if chunk_size is None and backend == "chunked":
        chunk_size = 3
    _, hist = run_federated(model, policy, cfg, *data,
                            key=jax.random.PRNGKey(0), backend=backend,
                            chunk_size=chunk_size, tracer=tracer)
    return hist


# ---------------------------------------------------------------------------
# span mechanics
# ---------------------------------------------------------------------------

def test_span_nesting_and_ordering():
    """Spans record depth, enclosing parent, and a monotone sequence."""
    clock = iter(np.arange(0.0, 100.0, 0.5))
    sink = obs.MemorySink()
    tr = obs.Tracer(sink, clock=lambda: float(next(clock)))
    tr.set_round(1)
    with tr.span("plan"):
        with tr.span("stack"):
            pass
        with tr.span("local_train", backend="dense"):
            pass
    with tr.span("eval"):
        pass
    spans = [r for r in sink.records if r["kind"] == "span"]
    by_name = {r["name"]: r for r in spans}
    # children exit before the parent
    assert [r["name"] for r in spans] == ["stack", "local_train", "plan",
                                          "eval"]
    assert by_name["stack"]["parent"] == "plan"
    assert by_name["local_train"]["parent"] == "plan"
    assert by_name["local_train"]["backend"] == "dense"
    assert by_name["plan"]["parent"] is None
    assert by_name["stack"]["depth"] == 1
    assert by_name["plan"]["depth"] == 0
    seqs = [r["seq"] for r in spans]
    assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)
    # injected clock (0.5s ticks): leaves last one tick, the parent spans
    # its children — enter(0.0) ... exit(2.5)
    assert by_name["stack"]["dur_s"] == pytest.approx(0.5)
    assert by_name["local_train"]["dur_s"] == pytest.approx(0.5)
    assert by_name["plan"]["dur_s"] == pytest.approx(2.5)
    assert all(r["round"] == 1 for r in spans)


def test_tracer_summary_aggregates():
    tr = obs.Tracer()
    with tr.span("plan"):
        pass
    with tr.span("plan"):
        pass
    tr.count("batch_elements_real", 10)
    tr.count("batch_elements_real", 5)
    tr.gauge("cohort_size", 8)
    s = tr.summary()
    assert s["phases"]["plan"]["count"] == 2
    assert s["counters"]["batch_elements_real"] == 15
    assert s["gauges"]["cohort_size"] == 8.0
    json.dumps(s)  # summary must be JSON-clean


def test_phase_order_within_round(setup):
    """In a real run every round's top-level spans appear in the canonical
    phase order (cohort -> plan -> stack -> train -> eval)."""
    sink = obs.MemorySink()
    tr = obs.Tracer(sink)
    _run(setup, "dense", tracer=tr)
    order = {p: i for i, p in enumerate(obs.PHASES)}
    for rnd in range(1, R + 1):
        names = [r["name"] for r in sink.records
                 if r["kind"] == "span" and r["round"] == rnd
                 and r["depth"] == 0]
        assert names, f"round {rnd} recorded no spans"
        idx = [order[n] for n in names if n in order]
        assert idx == sorted(idx), f"round {rnd} phases out of order: {names}"


# ---------------------------------------------------------------------------
# NullTracer: zero-overhead default
# ---------------------------------------------------------------------------

def test_null_tracer_overhead_budget(setup):
    """The NullTracer's total per-run cost stays under 1% of a dense run.

    Comparing two full wall-clock runs at 1% precision flakes on shared
    runners, so measure the per-call no-op cost directly and price the
    instrumented call sites a 10-round dense run actually executes."""
    null = obs.NULL_TRACER
    n = 50_000
    t0 = obs.now()
    for _ in range(n):
        with null.span("plan", backend="dense"):
            pass
        null.count("batch_elements_real", 7)
        null.gauge("cohort_size", 8)
        null.event("round", t=0)
        null.active  # the hot-path guard itself
    per_group = (obs.now() - t0) / n

    t0 = obs.now()
    hist = _run(setup, "dense", tracer=None)
    wall = obs.now() - t0
    assert hist.rounds, "dense run executed no rounds"
    # ~10 instrumented call groups per round is far above the real count
    groups = 10 * 10
    assert groups * per_group < 0.01 * wall, (
        f"NullTracer cost {groups * per_group:.6f}s vs 1% budget "
        f"{0.01 * wall:.6f}s")


def test_null_tracer_api_is_inert():
    null = obs.NULL_TRACER
    assert null.active is False
    with null.span("anything", junk=1) as sp:
        assert sp is not None
    null.set_round(3)
    null.count("x")
    null.gauge("y", 1.0)
    null.event("round", t=0)
    assert null.summary() == {}
    null.close()


# ---------------------------------------------------------------------------
# tracing on == tracing off, every backend
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["dense", "chunked", "shard_map",
                                     "temporal"])
def test_tracing_preserves_trajectories(setup, backend):
    """Identical History with tracing on vs off — telemetry must never
    touch PRNG keys or numerics, on any execution backend."""
    base = _run(setup, backend, tracer=None)
    tr = obs.Tracer(obs.MemorySink())
    traced = _run(setup, backend, tracer=tr)
    a, b = base.as_dict(), traced.as_dict()
    tel = b.pop("telemetry")
    a.pop("telemetry")
    assert a == b
    # and the traced run actually recorded its rounds
    assert tel["phases"]["local_train"]["count"] >= len(traced.rounds)
    assert len(tel["ledger"]) == len(traced.rounds)
    assert tel["counters"]["batch_elements_real"] > 0


def test_chunked_splits_train_and_aggregate(setup):
    """Only the chunked backend can separate local_train from the final
    aggregate apply; the fused backends fold both into local_train."""
    sink = obs.MemorySink()
    tr = obs.Tracer(sink)
    _run(setup, "chunked", tracer=tr, chunk_size=2)
    names = {r["name"] for r in sink.records if r["kind"] == "span"}
    assert "aggregate" in names and "local_train" in names


# ---------------------------------------------------------------------------
# History round-trip (satellite: replans as_dict fix)
# ---------------------------------------------------------------------------

def test_history_as_dict_round_trips_replan_events():
    ev = ReplanEvent(round=3, reachable=17, U_est=8, budget_left=12.5,
                     T_tail=[1.0, 0.9], m=1.1, objective=0.42, steps=100)
    hist = History(times=[1.0], rounds=[1], accuracy=[0.5], deadlines=[1.0],
                   train_loss=[2.0], replans=[ev], method="adel")
    d = hist.as_dict()
    blob = json.dumps(d)                   # must not raise on the dataclass
    back = json.loads(blob)
    assert back["replans"] == [ev.as_dict()]
    assert back["replans"][0]["round"] == 3
    # dict entries (what the runtime appends) pass through unchanged
    hist2 = History(replans=[ev.as_dict()])
    assert hist2.as_dict()["replans"] == [ev.as_dict()]


# ---------------------------------------------------------------------------
# ledger math
# ---------------------------------------------------------------------------

def test_expected_depth_exact():
    """E[min(z, L)] matches the closed form at the edges and Monte Carlo
    in the middle."""
    assert expected_depth(np.asarray([0.0]), 5)[0] == pytest.approx(0.0)
    # lam tiny -> E[min(z,L)] ~ E[z] = lam
    assert expected_depth(np.asarray([1e-4]), 5)[0] == pytest.approx(
        1e-4, rel=1e-3)
    # lam huge -> saturates at L
    assert expected_depth(np.asarray([200.0]), 5)[0] == pytest.approx(
        5.0, abs=1e-6)
    rng = np.random.default_rng(0)
    for lam, L in ((0.7, 3), (2.5, 4), (6.0, 8)):
        mc = np.minimum(rng.poisson(lam, size=200_000), L).mean()
        assert expected_depth(np.asarray([lam]), L)[0] == pytest.approx(
            mc, abs=0.02)


def test_drift_summary_fields():
    rows = [{"T_deadline": 1.0, "sim_round": 1.0, "wall_round_s": 0.5,
             "cohort": 4, "missed": 2, "zero_contrib": 1,
             "depth_real": 1.5, "depth_pred": 1.0, "p1_pred": 0.1,
             "layer1_zero": False, "pred_full_s": 2.0}] * 3
    d = drift_summary(rows)
    assert d["rounds"] == 3
    assert d["depth_drift_mean"] == pytest.approx(0.5)
    assert d["miss_rate"] == pytest.approx(0.5)
    assert d["zero_rate"] == pytest.approx(0.25)
    assert d["wall_per_sim_mean"] == pytest.approx(0.5)
    assert d["deadline_vs_full_wait"] == pytest.approx(0.5)
    assert drift_summary([]) == {}


def test_ledger_rows_in_real_run(setup):
    sink = obs.MemorySink()
    tr = obs.Tracer(sink)
    hist = _run(setup, "dense", tracer=tr)
    model, cfg, _, _ = setup
    rows = [r for r in sink.records if r.get("kind") == "round"]
    assert len(rows) == len(hist.rounds)
    for r in rows:
        assert r["cohort"] == U
        assert 0.0 <= r["depth_real"] <= model.L
        assert r["full"] + r["missed"] == r["cohort"]
        assert "depth_pred" in r and "pred_full_s" in r
        # the deadline should undercut the synchronized full-depth wait
        assert r["T_deadline"] < r["pred_full_s"]
    # sim clock in the ledger mirrors the History clock
    assert [r["sim_total"] for r in rows] == pytest.approx(hist.times)


# ---------------------------------------------------------------------------
# sinks + timeline renderer
# ---------------------------------------------------------------------------

def test_jsonl_sink_and_timeline(tmp_path, setup):
    path = os.path.join(tmp_path, "events", "run.jsonl")
    tr = obs.make_tracer(path)
    assert tr.active
    _run(setup, "dense", tracer=tr)
    tr.close()
    records = load_events(path)
    assert records and any(r["kind"] == "round" for r in records)
    assert phase_table(records)
    text = render(records, title="run")
    assert "phase timeline" in text
    assert "clock-model ledger" in text
    assert "stragglers / deadline misses" in text
    assert "drift summary" in text
    # one row per executed round in the ledger table
    assert f"\n    {R}  " in text or f"\n{R}  " in text.replace("  ", "  ")


def test_load_events_skips_torn_lines(tmp_path):
    p = os.path.join(tmp_path, "torn.jsonl")
    with open(p, "w") as f:
        f.write(json.dumps({"kind": "span", "name": "plan", "round": 1,
                            "dur_s": 0.1}) + "\n")
        f.write('{"kind": "round", "t": 0, "T_dead')   # crashed mid-write
    recs = load_events(p)
    assert len(recs) == 1 and recs[0]["name"] == "plan"


def test_make_tracer_defaults_to_null():
    assert obs.make_tracer() is obs.NULL_TRACER
    assert obs.make_tracer(None) is obs.NULL_TRACER
