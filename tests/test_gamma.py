"""Auxiliary Lemma (Appendix E) + Lemma 1 machinery: the vectorized
regularized upper incomplete gamma ladder."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st
from scipy.special import gammaincc
from scipy.stats import poisson

from repro.core.gamma import (layer_q, log_q_gamma_all, p_no_contributor,
                              poisson_cdf, q_gamma, q_gamma_all)


@pytest.mark.parametrize("s", [1, 2, 5, 17])
@pytest.mark.parametrize("x", [0.0, 0.3, 1.0, 7.5, 40.0])
def test_matches_scipy(s, x):
    ours = float(q_gamma(s, jnp.float32(x)))
    ref = float(gammaincc(s, x))          # scipy regularized upper gamma
    assert abs(ours - ref) < 1e-5, (s, x, ours, ref)


def test_poisson_cdf_identity():
    """Auxiliary Lemma: Q(s, x) = P[Poisson(x) <= s-1]."""
    for lam in [0.1, 2.0, 9.0]:
        for k in range(6):
            ours = float(poisson_cdf(k, jnp.float32(lam)))
            ref = float(poisson.cdf(k, lam))
            assert abs(ours - ref) < 1e-5


def test_ladder_consistent():
    x = jnp.asarray([0.5, 3.0, 12.0])
    all_q = q_gamma_all(8, x)
    for s in range(1, 9):
        np.testing.assert_allclose(np.asarray(all_q[:, s - 1]),
                                   [float(q_gamma(s, xx)) for xx in x],
                                   rtol=1e-5, atol=1e-6)


def test_layer_monotonicity():
    """Paper: p_t^l decreases with layer index l (layer L easiest)."""
    L = 10
    q = np.asarray(layer_q(L, jnp.float32(4.0)))
    assert q.shape == (L,)
    assert np.all(np.diff(q) <= 1e-7)     # nonincreasing in l
    assert q[-1] == pytest.approx(np.exp(-4.0), rel=1e-4)  # Q(1,x)=e^-x


@settings(deadline=None, max_examples=40)
@given(st.integers(1, 30), st.floats(0.01, 60.0), st.integers(1, 40))
def test_lemma1_bound_properties(L, x, U):
    """0 <= Q^U <= 1, monotone in U, and log-stable for large x."""
    p = np.asarray(p_no_contributor(L, jnp.float32(x), U))
    assert p.shape == (L,)
    assert np.all(p >= 0) and np.all(p <= 1 + 1e-6)
    p2 = np.asarray(p_no_contributor(L, jnp.float32(x), U + 1))
    assert np.all(p2 <= p + 1e-6)         # more users -> less likely empty
    assert np.all(np.isfinite(p))
