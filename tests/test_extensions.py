"""Beyond-paper extensions: R-optimization (paper §III-D) and pilot-round
constant calibration."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gamma import q_gamma, q_inv
from repro.core.scheduler import solve, solve_rounds
from repro.core.types import AnalysisConfig


def test_q_inv_inverts_q():
    for s in (3, 10, 40):
        for target in (0.9, 0.5, 0.1):
            x = q_inv(s, target)
            assert abs(float(q_gamma(s, jnp.float32(x))) - target) < 1e-3


def test_solver_feasible_by_construction():
    """Every solved schedule satisfies the Problem-2 constraints."""
    for seed in (0, 1):
        cfg = AnalysisConfig.default(U=8, L=12, R=20, T_max=150.0,
                                     eta0=0.5, seed=seed)
        sch = solve(cfg, "adam", steps=500)
        assert np.all(np.diff(sch.T) <= 1e-5)              # nonincreasing
        assert sch.T.sum() <= cfg.T_max * (1 + 1e-5)       # budget
        assert np.all(sch.p1 < 0.2), sch.p1.max()          # Lemma-3 validity
        assert np.all(sch.batch_sizes(cfg) >= 1)


def test_solve_rounds_at_least_as_good_as_fixed_R():
    cfg = AnalysisConfig.default(U=10, L=10, R=30, T_max=120.0,
                                 eta0=0.5, seed=0)
    fixed = solve(cfg, "adam", steps=400)
    sch, cfg_r = solve_rounds(cfg, "adam", steps=400)
    assert sch.objective <= fixed.objective * (1 + 1e-4)
    assert cfg_r.R in range(2, 61)
    assert sch.T.shape == (cfg_r.R,)


def test_calibrate_constants_shapes_and_positive():
    from repro.data.synthetic import make_image_dataset
    from repro.fl.calibrate import calibrate_constants
    from repro.fl.partition import iid_partition, stack_clients
    from repro.models.paper_models import make_mlp

    x, y, _, _ = make_image_dataset("mnist", n_train=300, n_test=10, seed=0)
    U = 4
    parts = iid_partition(len(y), U, seed=0)
    cx, cy, counts = stack_clients(x, y, parts)
    model = make_mlp()
    cfg = AnalysisConfig.default(U=U, L=model.L, R=5, T_max=10.0, seed=0)
    params = model.init(jax.random.PRNGKey(0))
    out = calibrate_constants(cfg, model, params, cx, cy, counts,
                              n_probe=16)
    assert out.sigma2.shape == (U,)
    assert np.all(out.sigma2 > 0)
    assert out.G2 > 0
    # G2 must upper-bound the full-gradient norm component
    assert out.G2 >= 0.0
