"""Aggregation invariants (Eq. 5) incl. hypothesis property tests and the
shard_map/psum path equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.aggregation import (aggregate_grads, aggregate_grads_local,
                                    layer_coefficients, masked_mean_grads)


def _rand(shape, seed):
    return jax.random.normal(jax.random.PRNGKey(seed), shape)


@settings(deadline=None, max_examples=30)
@given(st.integers(2, 9), st.integers(1, 7), st.integers(1, 5),
       st.integers(0, 2 ** 30))
def test_full_mask_recovers_fedavg(U, L, F, seed):
    """With everyone contributing and p = 0, Eq. (5) is exactly FedAvg."""
    g = np.random.default_rng(seed).normal(size=(U, L, F)).astype(np.float32)
    mask = jnp.ones((U, L))
    p = jnp.zeros((L,))
    agg = aggregate_grads({"w": jnp.asarray(g)}, {"w": jnp.arange(L)},
                          mask, p)["w"]
    np.testing.assert_allclose(np.asarray(agg), g.mean(0), rtol=2e-5,
                               atol=1e-6)


@settings(deadline=None, max_examples=30)
@given(st.integers(2, 8), st.integers(2, 6), st.integers(0, 2 ** 30),
       st.floats(0.0, 0.19))
def test_scale_equivariance(U, L, seed, p_val):
    """agg(c * g) = c * agg(g) — aggregation is linear in the gradients."""
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=(U, L, 3)).astype(np.float32))
    mask = jnp.asarray((rng.random((U, L)) > 0.4).astype(np.float32))
    p = jnp.full((L,), p_val, jnp.float32)
    ids = {"w": jnp.arange(L)}
    a1 = aggregate_grads({"w": 2.5 * g}, ids, mask, p)["w"]
    a2 = 2.5 * aggregate_grads({"w": g}, ids, mask, p)["w"]
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2), rtol=2e-5,
                               atol=1e-6)


def test_empty_layer_zero_and_correction():
    U, L = 5, 4
    g = jnp.ones((U, L, 2))
    mask = jnp.ones((U, L)).at[:, 2].set(0.0)
    p = jnp.asarray([0.0, 0.1, 0.5, 0.19])
    agg = aggregate_grads({"w": g}, {"w": jnp.arange(L)}, mask, p)["w"]
    np.testing.assert_allclose(np.asarray(agg[2]), 0.0)
    np.testing.assert_allclose(np.asarray(agg[1]), 1 / 0.9, rtol=1e-6)


def test_masked_mean_no_correction():
    U, L = 4, 3
    g = jnp.ones((U, L, 2))
    mask = jnp.ones((U, L))
    out = masked_mean_grads({"w": g}, {"w": jnp.arange(L)}, mask)["w"]
    np.testing.assert_allclose(np.asarray(out), 1.0, rtol=1e-6)


def test_shard_map_psum_path_matches():
    """aggregate_grads_local under shard_map == aggregate_grads globally."""
    from jax.sharding import Mesh, PartitionSpec as P
    try:
        from jax import shard_map            # jax >= 0.5
    except ImportError:
        from jax.experimental.shard_map import shard_map

    U, L, F = 4, 3, 6   # single CPU device -> 1 shard holding all clients
    g = _rand((U, L, F), 0)
    mask = (jax.random.uniform(jax.random.PRNGKey(1), (U, L)) > 0.3
            ).astype(jnp.float32)
    p = jnp.full((L,), 0.1)
    ids = {"w": jnp.arange(L)}

    ref = aggregate_grads({"w": g}, ids, mask, p)["w"]

    mesh = Mesh(np.asarray(jax.devices()[:1]), ("clients",))
    fn = shard_map(
        lambda gg, mm: aggregate_grads_local({"w": gg}, ids, mm, p,
                                             "clients")["w"],
        mesh=mesh, in_specs=(P("clients"), P("clients")),
        out_specs=P())
    out = fn(g, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5)


@settings(deadline=None, max_examples=25)
@given(st.integers(2, 8), st.integers(2, 6), st.integers(0, 2 ** 30))
def test_coefficients_rowsum(U, L, seed):
    """For layers with k>0 contributors, coefficients sum to 1/(1-p_l);
    empty layers sum to 0 (update preserved)."""
    rng = np.random.default_rng(seed)
    mask = jnp.asarray((rng.random((U, L)) > 0.5).astype(np.float32))
    p = jnp.asarray(rng.uniform(0, 0.19, L).astype(np.float32))
    c = layer_coefficients(mask, p)
    sums = np.asarray(c.sum(0))
    counts = np.asarray(mask.sum(0))
    expect = np.where(counts > 0, 1.0 / (1.0 - np.asarray(p)), 0.0)
    np.testing.assert_allclose(sums, expect, rtol=1e-5, atol=1e-6)
