"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.adel_agg import adel_agg, adel_agg_q8
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ops import (adel_aggregate_pallas, gqa_flash,
                               ssd_chunked_pallas)
from repro.kernels.ref import (adel_agg_q8_ref, adel_agg_ref,
                               flash_attention_ref, ssd_scan_ref)


def _qs(shape, seed, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(seed), shape).astype(dtype)


TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,KV,S,hd", [
    (1, 2, 2, 128, 64),      # MHA
    (2, 4, 2, 256, 64),      # GQA g=2
    (1, 8, 1, 128, 128),     # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, H, KV, S, hd, dtype):
    q = _qs((B, H, S, hd), 0, dtype)
    k = _qs((B, KV, S, hd), 1, dtype)
    v = _qs((B, KV, S, hd), 2, dtype)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                          interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


@pytest.mark.parametrize("window", [32, 64, 100])
def test_flash_attention_window(window):
    B, H, KV, S, hd = 1, 2, 1, 256, 64
    q, k, v = (_qs((B, H, S, hd), 0), _qs((B, KV, S, hd), 1),
               _qs((B, KV, S, hd), 2))
    out = flash_attention(q, k, v, causal=True, window=window,
                          block_q=64, block_k=64, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_noncausal_cross_shapes():
    """Sq != Sk (cross-attention shape)."""
    B, H, KV, hd = 2, 2, 2, 64
    q = _qs((B, H, 128, hd), 0)
    k = _qs((B, KV, 256, hd), 1)
    v = _qs((B, KV, 256, hd), 2)
    out = flash_attention(q, k, v, causal=False, block_q=64, block_k=64,
                          interpret=True)
    ref = flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_gqa_flash_model_layout():
    B, S, H, KV, hd = 2, 128, 4, 2, 64
    q = _qs((B, S, H, hd), 3)
    k = _qs((B, S, KV, hd), 4)
    v = _qs((B, S, KV, hd), 5)
    out = gqa_flash(q, k, v, block_q=64, block_k=64, interpret=True)
    ref = jnp.swapaxes(flash_attention_ref(
        jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
        jnp.swapaxes(v, 1, 2)), 1, 2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (1, 64, 2, 16, 8, 16),
    (2, 128, 4, 32, 16, 32),
    (1, 256, 1, 64, 128, 64),     # mamba2-370m block dims
])
def test_ssd_scan_sweep(B, S, H, P, N, chunk):
    x = _qs((B, S, H, P), 0)
    dt = jax.nn.softplus(_qs((B, S, H), 1))
    A = jax.nn.softplus(_qs((H,), 2))
    b = 0.3 * _qs((B, S, N), 3)
    c = 0.3 * _qs((B, S, N), 4)
    out = ssd_chunked_pallas(x, dt, A, b, c, chunk=chunk, interpret=True)
    ref = ssd_scan_ref(x, dt, A, b, c)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-4, atol=3e-4)


def test_ssd_scan_state_carry_vs_chunking():
    """Chunk size must not change the result (state carried correctly)."""
    B, S, H, P, N = 1, 128, 2, 16, 8
    x = _qs((B, S, H, P), 0)
    dt = jax.nn.softplus(_qs((B, S, H), 1))
    A = jax.nn.softplus(_qs((H,), 2))
    b, c = 0.3 * _qs((B, S, N), 3), 0.3 * _qs((B, S, N), 4)
    o1 = ssd_chunked_pallas(x, dt, A, b, c, chunk=16, interpret=True)
    o2 = ssd_chunked_pallas(x, dt, A, b, c, chunk=128, interpret=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=3e-4, atol=3e-4)


# ---------------------------------------------------------------------------
# ADEL aggregation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("U,L,F,bf", [
    (4, 3, 512, 512),
    (16, 8, 1024, 256),
    (7, 5, 512, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_adel_agg_sweep(U, L, F, bf, dtype):
    g = _qs((U, L, F), 0, dtype)
    c = jax.random.uniform(jax.random.PRNGKey(1), (U, L)).astype(dtype)
    out = adel_agg(g, c, block_f=bf, interpret=True)
    ref = adel_agg_ref(g, c)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


@pytest.mark.parametrize("U,L,F,bf", [
    (3, 2, 300, 128),     # F not a multiple of block_f
    (4, 3, 130, 512),     # F < block_f and odd
    (2, 2, 7, 4),         # tiny, non-multiple
])
def test_adel_agg_nonmultiple_feature_dim(U, L, F, bf):
    """The kernel pads the flattened feature dim and slices the output."""
    g = _qs((U, L, F), 0)
    c = jax.random.uniform(jax.random.PRNGKey(1), (U, L))
    out = adel_agg(g, c, block_f=bf, interpret=True)
    assert out.shape == (L, F)
    ref = adel_agg_ref(g, c)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# quantized ADEL aggregation (int8 wire payloads)
# ---------------------------------------------------------------------------

def _quantize(g):
    """The wire's symmetric int8 absmax quantization of (U, L, F) deltas."""
    amax = jnp.max(jnp.abs(g), axis=-1)
    scale = amax / 127.0
    inv = jnp.where(amax > 0, 127.0 / amax, 0.0)
    return jnp.rint(g * inv[..., None]).astype(jnp.int8), scale


@pytest.mark.parametrize("U,L,F,bf", [
    (4, 3, 512, 512),
    (7, 5, 300, 128),     # odd U, F not a multiple of block_f
    (3, 2, 130, 64),      # F < 2*block_f and non-multiple
    (2, 2, 7, 4),         # tiny, non-multiple
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_adel_agg_q8_sweep(U, L, F, bf, dtype):
    """Fused dequantize+weight+accumulate vs the pure-jnp oracle (the
    acceptance tolerance is atol 1e-2 in interpret mode)."""
    q, scale = _quantize(_qs((U, L, F), 0))
    c = jax.random.uniform(jax.random.PRNGKey(1), (U, L))
    out = adel_agg_q8(q, scale.astype(dtype), c.astype(dtype),
                      block_f=bf, interpret=True)
    assert out.shape == (L, F) and out.dtype == jnp.float32
    ref = adel_agg_q8_ref(q, scale.astype(dtype), c.astype(dtype))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref, np.float32),
                               atol=1e-2, rtol=1e-2)


def test_adel_agg_q8_zero_coefficient_rows():
    """Clients with all-zero Eq. 5 coefficients (deadline misses at depth
    0) must contribute nothing — dropping their rows gives the same sum."""
    U, L, F = 6, 4, 96
    q, scale = _quantize(_qs((U, L, F), 2))
    c = jax.random.uniform(jax.random.PRNGKey(3), (U, L))
    c = c.at[1].set(0.0).at[4].set(0.0)
    out = adel_agg_q8(q, scale, c, block_f=64, interpret=True)
    keep = jnp.asarray([0, 2, 3, 5])
    ref = adel_agg_q8_ref(q[keep], scale[keep], c[keep])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


def test_adel_agg_q8_zero_scale_layer():
    """An all-zero delta layer quantizes to scale 0 and must aggregate to
    exactly zero (the inv-scale guard, not NaN/inf)."""
    U, L, F = 3, 2, 64
    g = _qs((U, L, F), 4).at[:, 1, :].set(0.0)
    q, scale = _quantize(g)
    c = jnp.ones((U, L))
    out = adel_agg_q8(q, scale, c, block_f=64, interpret=True)
    assert np.all(np.isfinite(np.asarray(out)))
    np.testing.assert_array_equal(np.asarray(out[1]), 0.0)


def test_adel_agg_q8_dequant_error_bound():
    """End-to-end quantize -> fused aggregate stays within the absmax/254
    per-element bound times the summed coefficients."""
    U, L, F = 5, 3, 256
    g = _qs((U, L, F), 5)
    q, scale = _quantize(g)
    c = jax.random.uniform(jax.random.PRNGKey(6), (U, L))
    out = adel_agg_q8(q, scale, c, block_f=128, interpret=True)
    dense = adel_agg_ref(g, c)
    bound = jnp.sum(c * jnp.max(jnp.abs(g), axis=-1) / 254.0, axis=0)
    err = jnp.max(jnp.abs(out - dense), axis=-1)
    assert np.all(np.asarray(err) <= np.asarray(bound) * 1.001)


def test_adel_agg_pytree_matches_reference_path():
    from repro.core.aggregation import aggregate_grads
    U, L = 5, 4
    key = jax.random.PRNGKey(3)
    grads = {"a": _qs((U, L, 24, 8), 0), "b": _qs((U, 10), 1)}
    ids = {"a": jnp.arange(L), "b": jnp.int32(1)}
    mask = (jax.random.uniform(key, (U, L)) > 0.4).astype(jnp.float32)
    p = jnp.full((L,), 0.08)
    out_k = adel_aggregate_pallas(grads, ids, mask, p, interpret=True)
    out_r = aggregate_grads(grads, ids, mask, p)
    for k in grads:
        np.testing.assert_allclose(np.asarray(out_k[k]),
                                   np.asarray(out_r[k]), rtol=2e-5,
                                   atol=1e-6)
