"""Population API: parametric-vs-materialized fidelity, cohort
determinism, million-device O(cohort) sampling, hierarchical two-tier
aggregation invariance, and legacy-vs-new ``run_fleet`` bit-identity."""
import numpy as np
import pytest

from repro.configs.base import FleetConfig
from repro.core.aggregation import aggregate_grads, aggregate_grads_chunk
from repro.data.synthetic import make_image_dataset
from repro.fl.spec import ExecSpec
from repro.fleet.availability import make_availability
from repro.fleet.engine import partition_fleet, reference_config, run_fleet
from repro.fleet.population import (MaterializedPopulation,
                                    ParametricPopulation, Population,
                                    PopulationSpec, make_population)
from repro.fleet.profiles import PRESETS, fleet_from_config, make_fleet
from repro.models.paper_models import make_mlp


@pytest.fixture(scope="module")
def fleet_setup():
    x_tr, y_tr, x_te, y_te = make_image_dataset(
        "mnist", n_train=1000, n_test=250, seed=0, noise_std=1.0)
    fleet = make_fleet("longtail-mobile", 200, seed=0)
    data = partition_fleet(x_tr, y_tr, x_te, y_te, 200, alpha=0.5, seed=0)
    return fleet, data


# ---------------------------------------------------------------------------
# parametric fidelity: lazy draws reproduce the preset's tier statistics
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("preset", sorted(PRESETS))
def test_parametric_matches_preset_quantiles(preset):
    """Lazy per-device draws reproduce the reference draw's recorded
    P/B q05/q50/q95 per memory tier — the fleet_smoke contract stats."""
    pop = ParametricPopulation(preset, 1_000_000, seed=0)
    ref = make_fleet(preset, 4096, seed=0)
    ids = np.arange(6000, dtype=np.int64) * 167         # spread over the pop
    P, B, tier = pop.profiles(ids)
    assert P.shape == B.shape == tier.shape == (6000,)
    assert (P > 0).all() and (B > 0).all()
    for k in np.unique(ref.tier):
        sel, rsel = tier == k, ref.tier == k
        if sel.sum() < 200:
            continue
        for drawn, refv in ((P[sel], ref.P[rsel]), (B[sel], ref.B[rsel])):
            got = np.quantile(drawn, [0.05, 0.5, 0.95])
            want = np.quantile(refv, [0.05, 0.5, 0.95])
            np.testing.assert_allclose(got, want, rtol=0.30)
    # tier mix matches the reference draw's
    frac = np.bincount(tier, minlength=3) / len(tier)
    want = np.bincount(ref.tier, minlength=3) / ref.size
    np.testing.assert_allclose(frac, want, atol=0.05)


def test_parametric_profiles_pure_in_device_id():
    """A device's profile is a pure function of (seed, id): re-querying or
    querying inside a different batch never changes it."""
    pop = ParametricPopulation("bimodal-edge", 10**6, seed=3)
    ids = np.asarray([7, 123_456, 999_999])
    P1, B1, t1 = pop.profiles(ids)
    P2, B2, t2 = ParametricPopulation("bimodal-edge", 10**6,
                                      seed=3).profiles(ids)
    np.testing.assert_array_equal(P1, P2)
    np.testing.assert_array_equal(B1, B2)
    np.testing.assert_array_equal(t1, t2)
    Pb, _, _ = pop.profiles(np.arange(10**6 - 10, 10**6))
    np.testing.assert_array_equal(Pb[-1], P1[-1])
    # ... and a different seed gives a different population
    P3, _, _ = ParametricPopulation("bimodal-edge", 10**6,
                                    seed=4).profiles(ids)
    assert not np.array_equal(P1, P3)


# ---------------------------------------------------------------------------
# cohort sampling: determinism + million-device scale
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", ["uniform", "power-of-choice",
                                      "stratified"])
def test_fixed_seed_identical_cohorts(strategy):
    pop = ParametricPopulation("longtail-mobile", 500_000, seed=0,
                               availability="bernoulli",
                               availability_kwargs=(("rate", 0.7),))
    draws1 = [pop.sample_cohort(t, np.random.default_rng([2077, 5]), U=16,
                                strategy=strategy) for t in range(3)]
    draws2 = [pop.sample_cohort(t, np.random.default_rng([2077, 5]), U=16,
                                strategy=strategy) for t in range(3)]
    for a, b in zip(draws1, draws2):
        np.testing.assert_array_equal(a.ids, b.ids)
        np.testing.assert_array_equal(a.P, b.P)
        assert a.available == b.available


def test_million_device_cohort_is_cohort_sized():
    import time
    pop = ParametricPopulation("longtail-mobile", 1_000_000, seed=0,
                               availability="bernoulli",
                               availability_kwargs=(("rate", 0.8),),
                               regions=4)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    draw = pop.sample_cohort(0, rng, U=64)
    dt = time.perf_counter() - t0
    assert dt < 1.0, f"million-device cohort draw took {dt:.2f}s"
    assert draw.size == 64
    assert len(np.unique(draw.ids)) == 64                # distinct devices
    assert draw.ids.min() >= 0 and draw.ids.max() < 1_000_000
    # Binomial(1e6, 0.8) concentrates hard around 800k
    assert abs(draw.available - 800_000) < 5_000
    np.testing.assert_array_equal(draw.region, draw.ids % 4)
    # planning surface works without materializing the fleet
    ref = reference_config(pop, U=16, L=4, R=5, T_max=20.0)
    assert ref.P.shape == (16,) and (np.diff(ref.P) >= 0).all()
    assert pop.expected_reachable(0, 3).shape == (3,)


# ---------------------------------------------------------------------------
# spec / constructor surface
# ---------------------------------------------------------------------------

def test_make_population_forms(fleet_setup):
    fleet, _ = fleet_setup
    # Population passthrough
    pop = MaterializedPopulation(fleet)
    assert make_population(pop) is pop
    # bare Fleet wrap preserves the arrays bit-for-bit
    wrapped = make_population(fleet)
    assert isinstance(wrapped, MaterializedPopulation)
    np.testing.assert_array_equal(wrapped.fleet.P, fleet.P)
    # string source -> PopulationSpec.build
    para = make_population("parametric:datacenter", size=10_000, regions=2)
    assert isinstance(para, ParametricPopulation)
    assert para.size == 10_000 and para.regions == 2
    mat = make_population("uniform", size=64, availability="bernoulli",
                          availability_kwargs=(("rate", 0.5),))
    assert isinstance(mat, MaterializedPopulation) and mat.size == 64
    # FleetConfig routes through the same spec
    fc = FleetConfig(population="parametric:uniform", size=1000, regions=3)
    spec = fc.population_spec()
    assert spec.source == "parametric:uniform" and spec.regions == 3
    assert spec.build().size == 1000


def test_unknown_preset_lists_registered():
    with pytest.raises(ValueError, match="registered presets"):
        fleet_from_config(FleetConfig(preset="no-such-preset"))
    with pytest.raises(ValueError, match="registered presets"):
        make_population("parametric:no-such-preset", size=100)
    with pytest.raises(ValueError, match="regions"):
        PopulationSpec(source="uniform", regions=0)
    with pytest.raises(TypeError, match="unknown"):
        PopulationSpec.resolve(sise=100)


def test_population_spec_resolve_precedence():
    base = PopulationSpec(source="datacenter", size=300, regions=2)
    # explicit overrides win over base; unset fields inherit
    spec = PopulationSpec.resolve(base=base, size=900)
    assert spec.source == "datacenter" and spec.size == 900
    assert spec.regions == 2
    # a full spec passes through untouched
    assert PopulationSpec.resolve(base) is base


# ---------------------------------------------------------------------------
# legacy-vs-new run_fleet bit-identity + deprecation shims
# ---------------------------------------------------------------------------

def _legacy_run(fleet, data, **kw):
    avail = make_availability("bernoulli", fleet.size, seed=7, rate=0.6)
    with pytest.warns(DeprecationWarning):
        return run_fleet(make_mlp(), fleet, avail, data, **kw)


def test_legacy_positional_matches_population(fleet_setup):
    """The deprecated (model, fleet, availability, data) signature and the
    Population path produce byte-identical trajectories."""
    fleet, data = fleet_setup
    kw = dict(method="adel", rounds=4, cohort_size=12, chunk_size=6,
              solver_steps=150, seed=0)
    _, legacy = _legacy_run(fleet, data, **kw)
    pop = MaterializedPopulation(
        fleet, make_availability("bernoulli", fleet.size, seed=7, rate=0.6))
    _, new = run_fleet(make_mlp(), pop, data=data, **kw)
    assert legacy.rounds == new.rounds
    assert legacy.available == new.available
    np.testing.assert_array_equal(legacy.accuracy, new.accuracy)
    np.testing.assert_array_equal(legacy.train_loss, new.train_loss)
    np.testing.assert_array_equal(legacy.times, new.times)
    np.testing.assert_array_equal(legacy.deadlines, new.deadlines)


def test_legacy_shim_strict_mode(fleet_setup, monkeypatch):
    fleet, data = fleet_setup
    monkeypatch.setenv("REPRO_EXEC_STRICT", "1")
    avail = make_availability("bernoulli", fleet.size, seed=7, rate=0.6)
    with pytest.raises(ValueError, match="Population"):
        run_fleet(make_mlp(), fleet, avail, data, rounds=1, cohort_size=4)


# ---------------------------------------------------------------------------
# hierarchical two-tier aggregation
# ---------------------------------------------------------------------------

def test_region_partition_aggregation_identity():
    """Summing per-region partial aggregates (evaluated against GLOBAL
    counts) equals the flat dense Eq. 5 fold — region count free."""
    rng = np.random.default_rng(0)
    U, L = 12, 4
    grads = {"w": rng.normal(size=(U, L, 5)).astype(np.float32),
             "b": rng.normal(size=(U, 3)).astype(np.float32)}
    ids = {"w": np.arange(L, dtype=np.int32),
           "b": np.asarray(2, np.int32)}
    mask = (rng.random((U, L)) < 0.7).astype(np.float32)
    p = np.asarray([0.1, 0.3, 0.2, 0.05], np.float32)
    dense = aggregate_grads(grads, ids, mask, p)
    counts = mask.sum(0)
    for regions in (1, 3, 4):
        rid = np.arange(U) % regions
        acc = None
        for g in range(regions):
            sel = np.flatnonzero(rid == g)
            part = aggregate_grads_chunk(
                {k: v[sel] for k, v in grads.items()}, ids, mask[sel], p,
                counts)
            acc = part if acc is None else {
                k: acc[k] + part[k] for k in acc}
        for k in dense:
            np.testing.assert_allclose(acc[k], dense[k], rtol=2e-5,
                                       atol=2e-6)


def test_hierarchical_single_region_bitexact_dense(fleet_setup):
    """regions=1 must fall through to the dense round step — bit-exact."""
    fleet, data = fleet_setup
    hists = {}
    for backend, regions in (("dense", 4), ("hierarchical", 1)):
        pop = MaterializedPopulation(
            fleet, make_availability("bernoulli", 200, seed=3, rate=0.6),
            regions=1)
        _, hists[backend] = run_fleet(
            make_mlp(), pop, data=data, method="adel", rounds=3,
            cohort_size=10, solver_steps=150, seed=0,
            exec=ExecSpec(backend=backend, regions=regions))
    a, b = hists["dense"], hists["hierarchical"]
    assert a.available == b.available
    np.testing.assert_array_equal(a.accuracy, b.accuracy)
    np.testing.assert_array_equal(a.train_loss, b.train_loss)
    np.testing.assert_array_equal(a.times, b.times)


@pytest.mark.parametrize("method", ["adel", "heterofl"])
def test_hierarchical_multi_region_equivalence(fleet_setup, method):
    """4 edge regions vs flat dense: identical clock + cohort draws, same
    learning trajectory up to float summation order."""
    fleet, data = fleet_setup
    hists = {}
    for backend in ("dense", "hierarchical"):
        pop = MaterializedPopulation(
            fleet, make_availability("markov", 200, seed=1,
                                     p_off_to_on=0.4, p_on_to_off=0.1),
            regions=4)
        _, hists[backend] = run_fleet(
            make_mlp(), pop, data=data, method=method, rounds=4,
            cohort_size=16, solver_steps=150, seed=0, eta0=1.0,
            exec=ExecSpec(backend=backend, regions=4))
    a, b = hists["dense"], hists["hierarchical"]
    assert a.rounds == b.rounds and a.available == b.available
    np.testing.assert_allclose(a.times, b.times, rtol=1e-6)
    np.testing.assert_allclose(a.accuracy, b.accuracy, atol=0.015)
    np.testing.assert_allclose(a.train_loss, b.train_loss, rtol=0.02,
                               atol=0.02)


def test_hierarchical_region_telemetry(fleet_setup):
    """The runtime ledger records regions/region_max/region_pad when the
    hierarchical backend runs."""
    from repro import obs
    fleet, data = fleet_setup
    pop = MaterializedPopulation(
        fleet, make_availability("bernoulli", 200, seed=3, rate=0.7),
        regions=4)
    tracer = obs.Tracer()
    _, hist = run_fleet(make_mlp(), pop, data=data, method="adel", rounds=3,
                        cohort_size=16, solver_steps=150, seed=0,
                        exec=ExecSpec(backend="hierarchical", regions=4),
                        tracer=tracer)
    rows = tracer.rounds
    assert rows and all("regions" in r for r in rows)
    for r in rows:
        assert 1 <= r["regions"] <= 4
        assert r["region_pad"] >= r["region_max"]


def test_parametric_end_to_end_o_cohort(fleet_setup):
    """A million-device parametric population drives run_fleet at
    O(cohort): virtual data shards, hierarchical fold, walltime bounded."""
    import time
    _, data = fleet_setup
    pop = make_population("parametric:longtail-mobile", size=1_000_000,
                          availability="bernoulli",
                          availability_kwargs=(("rate", 0.7),), regions=4)
    t0 = time.perf_counter()
    _, hist = run_fleet(make_mlp(), pop, data=data, method="adel", rounds=3,
                        cohort_size=16, solver_steps=150, seed=0,
                        exec=ExecSpec(backend="hierarchical", regions=4))
    dt = time.perf_counter() - t0
    assert len(hist.accuracy) == 3
    assert all(600_000 < a < 800_000 for a in hist.available)
    assert dt < 120.0, f"1M-device 3-round run took {dt:.1f}s"


def test_population_protocol_replan_surface():
    """The replan hooks every trigger needs exist on both implementations."""
    for pop in (make_population("uniform", size=128),
                make_population("parametric:uniform", size=100_000)):
        assert isinstance(pop, Population)
        P, B = pop.replan_profile(8)
        assert P.shape == B.shape == (8,)
        assert pop.rate_max >= P.max() or pop.rate_max > 0
        d = pop.describe()
        assert {"fleet", "availability", "regions"} <= set(d)
