"""Hypothesis shim: use the real library when installed, otherwise a tiny
deterministic fallback.

The container image does not ship ``hypothesis``; property tests still run,
exercising each ``@given`` test on the boundary tuples (all-min, all-max)
plus a fixed number of seeded pseudo-random samples. Only the strategy
subset the suite actually uses (``integers``, ``floats``) is implemented.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    # redundant aliases mark the re-export (ruff F401)
    from hypothesis import given as given
    from hypothesis import settings as settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    import numpy as np

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, lo, hi, sample):
            self.lo, self.hi, self._sample = lo, hi, sample

        def sample(self, rng):
            return self._sample(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                int(min_value), int(max_value),
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                float(min_value), float(max_value),
                lambda rng: float(rng.uniform(min_value, max_value)))

    st = _Strategies()

    def settings(**_kwargs):
        """Accepted and ignored (fallback always runs a fixed sample count)."""
        def deco(fn):
            return fn
        return deco

    def given(*strats):
        def deco(fn):
            def wrapper():
                rng = np.random.default_rng(20260727)
                fn(*[s.lo for s in strats])
                fn(*[s.hi for s in strats])
                for _ in range(10):
                    fn(*[s.sample(rng) for s in strats])
            # NOT functools.wraps: copying __wrapped__ would make pytest
            # introspect the original signature and demand fixtures for the
            # strategy-bound parameters.
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco
