"""Wire-format compression (repro.core.compression): round-trip bounds,
payload layout, fused aggregation vs the dense Eq. 5 reference, and the
analytic byte accounting the benchmark gate matches exactly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregation import aggregate_grads, layer_coefficients
from repro.core.compression import (CompressionConfig, aggregate_compressed,
                                    compress_deltas, make_compression,
                                    payload_bytes)

U, L = 6, 4


def _tree(seed=0):
    """A stacked delta pytree + layer ids like the backends produce:
    one stacked-layer leaf, one whole-tensor (scalar-id) leaf."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    grads = {"w": jax.random.normal(k1, (U, L, 24, 8)),
             "head": jax.random.normal(k2, (U, 10))}
    ids = {"w": jnp.arange(L), "head": jnp.int32(L - 1)}
    params = {"w": jnp.zeros((L, 24, 8)), "head": jnp.zeros((10,))}
    return grads, ids, params


def _mask_p(seed=1):
    mask = (jax.random.uniform(jax.random.PRNGKey(seed), (U, L)) > 0.3)
    return mask.astype(jnp.float32), jnp.full((L,), 0.08)


# ---------------------------------------------------------------------------
# config / spec parsing
# ---------------------------------------------------------------------------

def test_make_compression_specs():
    assert make_compression(None).mode == "none"
    assert make_compression("int8").mode == "int8"
    cfg = make_compression(("topk8", 0.1))
    assert cfg.mode == "topk8" and cfg.top_k == 0.1
    assert make_compression(cfg) is cfg
    with pytest.raises(AssertionError):
        make_compression("zstd")
    with pytest.raises(AssertionError):
        CompressionConfig(mode="topk8", top_k=0.0)


def test_wire_scale():
    assert make_compression(None).wire_scale() == 1.0
    assert make_compression("int8").wire_scale() == 0.25
    assert make_compression(("topk8", 0.05)).wire_scale() == pytest.approx(
        0.0625)


# ---------------------------------------------------------------------------
# wire payload layout + round-trip error
# ---------------------------------------------------------------------------

def test_int8_payload_layout_and_roundtrip():
    grads, ids, _ = _tree()
    cfg = make_compression("int8")
    payload = compress_deltas(grads, ids, cfg)
    # flat list in jax.tree.flatten (sorted-key) order: head then w
    assert len(payload) == 2
    (q_h, s_h), (q_w, s_w) = payload
    assert q_h.dtype == jnp.int8 and q_h.shape == (U, 1, 10)
    assert s_h.dtype == jnp.float32 and s_h.shape == (U, 1)
    assert q_w.dtype == jnp.int8 and q_w.shape == (U, L, 24 * 8)
    assert s_w.shape == (U, L)
    # symmetric absmax: dequant error <= scale/2 = amax/254 per element
    flat = grads["w"].reshape(U, L, -1)
    deq = q_w.astype(jnp.float32) * s_w[..., None]
    amax = jnp.max(jnp.abs(flat), axis=-1, keepdims=True)
    assert np.all(np.abs(np.asarray(deq - flat)) <=
                  np.asarray(amax) / 254.0 + 1e-7)


def test_topk8_payload_keeps_largest_magnitudes():
    grads, ids, _ = _tree()
    cfg = make_compression(("topk8", 0.25))
    payload = compress_deltas(grads, ids, cfg)
    q_w, s_w, idx_w = payload[1]
    k = int(np.ceil(0.25 * 24 * 8))
    assert q_w.shape == (U, L, k) and idx_w.dtype == jnp.int32
    flat = np.abs(np.asarray(grads["w"].reshape(U, L, -1)))
    kept = np.take_along_axis(flat, np.asarray(idx_w), axis=-1)
    # every kept magnitude >= every dropped magnitude
    thresh = kept.min(axis=-1)
    mask = np.ones_like(flat, bool)
    np.put_along_axis(mask, np.asarray(idx_w), False, axis=-1)
    assert np.all(np.where(mask, flat, 0.0) <= thresh[..., None] + 1e-7)


# ---------------------------------------------------------------------------
# fused aggregation vs the dense reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("agg_impl", ["jnp", "pallas"])
def test_int8_aggregate_close_to_dense(agg_impl):
    grads, ids, params = _tree()
    mask, p = _mask_p()
    cfg = make_compression("int8")
    payload = compress_deltas(grads, ids, cfg)
    out = aggregate_compressed(payload, params, ids, mask, p, cfg=cfg,
                               agg_impl=agg_impl, interpret=True)
    ref = aggregate_grads(grads, ids, mask, p)
    for key in params:
        np.testing.assert_allclose(np.asarray(out[key]),
                                   np.asarray(ref[key]), atol=0.05)


def test_pallas_agg_matches_jnp_exactly():
    grads, ids, params = _tree(seed=7)
    mask, p = _mask_p(seed=8)
    cfg = make_compression("int8")
    payload = compress_deltas(grads, ids, cfg)
    a = aggregate_compressed(payload, params, ids, mask, p, cfg=cfg,
                             agg_impl="jnp")
    b = aggregate_compressed(payload, params, ids, mask, p, cfg=cfg,
                             agg_impl="pallas", interpret=True)
    for key in params:
        np.testing.assert_allclose(np.asarray(a[key]), np.asarray(b[key]),
                                   rtol=1e-6, atol=1e-6)


def test_topk_full_fraction_matches_int8():
    """top_k=1.0 keeps every entry: the scatter-add path must reproduce
    the dense int8 einsum."""
    grads, ids, params = _tree(seed=2)
    mask, p = _mask_p(seed=3)
    q8 = make_compression("int8")
    tk = make_compression(("topk8", 1.0))
    a = aggregate_compressed(compress_deltas(grads, ids, q8), params, ids,
                             mask, p, cfg=q8)
    b = aggregate_compressed(compress_deltas(grads, ids, tk), params, ids,
                             mask, p, cfg=tk)
    for key in params:
        np.testing.assert_allclose(np.asarray(a[key]), np.asarray(b[key]),
                                   rtol=1e-5, atol=1e-6)


def test_coeffs_override_matches_mask_path():
    """Temporal's per-client fold hands explicit Eq. 5 coefficient rows;
    summing the per-client folds must equal the one-shot aggregation."""
    grads, ids, params = _tree(seed=4)
    mask, p = _mask_p(seed=5)
    cfg = make_compression("int8")
    coeffs = layer_coefficients(mask, p)
    whole = aggregate_compressed(compress_deltas(grads, ids, cfg), params,
                                 ids, mask, p, cfg=cfg)
    acc = {k: jnp.zeros_like(v) for k, v in whole.items()}
    for u in range(U):
        g1 = jax.tree.map(lambda g: g[u:u + 1], grads)
        part = aggregate_compressed(compress_deltas(g1, ids, cfg), params,
                                    ids, None, None, cfg=cfg,
                                    coeffs=coeffs[u:u + 1])
        acc = jax.tree.map(jnp.add, acc, part)
    for key in params:
        np.testing.assert_allclose(np.asarray(acc[key]),
                                   np.asarray(whole[key]), rtol=1e-5,
                                   atol=1e-6)


# ---------------------------------------------------------------------------
# analytic byte accounting (the exact-match benchmark gate relies on this)
# ---------------------------------------------------------------------------

def test_payload_bytes_analytic():
    _, ids, params = _tree()
    # dense f32: (4*192 + 10) elements * 4 bytes * U clients
    n_el = L * 24 * 8 + 10
    logical, wire = payload_bytes(params, ids, U, make_compression(None))
    assert logical == wire == 4 * n_el * U
    logical, wire = payload_bytes(params, ids, U, make_compression("int8"))
    assert logical == 4 * n_el * U
    assert wire == (n_el + 4 * (L + 1)) * U         # 1B/el + f32 scales
    cfg = make_compression(("topk8", 0.05))
    k_w, k_h = int(np.ceil(0.05 * 192)), max(1, int(np.ceil(0.05 * 10)))
    _, wire = payload_bytes(params, ids, U, cfg)
    assert wire == (5 * (L * k_w + k_h) + 4 * (L + 1)) * U
    # int8 wire is >= 3.5x smaller than logical for real layer widths
    assert logical / payload_bytes(params, ids, U,
                                   make_compression("int8"))[1] > 3.5
