"""Lemma 1: p_t^l = P(|U_t^l| = 0) <= Q(L+1-l, T_t/m)^U, Monte-Carlo."""
import jax.numpy as jnp
import numpy as np

from repro.core.gamma import p_no_contributor
from repro.core.straggler import (exact_p_layers, poisson_rates,
                                  simulate_p_empirical)
from repro.core.types import AnalysisConfig


def _cfg(U=12, L=8, seed=3):
    return AnalysisConfig.default(U=U, L=L, R=10, T_max=100.0, seed=seed)


def test_lemma1_montecarlo_bound():
    cfg = _cfg()
    T_d, m = 9.0, 1.2
    emp = simulate_p_empirical(T_d, m, cfg, n_trials=4000)
    bound = np.asarray(p_no_contributor(cfg.L, jnp.float32(T_d / m), cfg.U))
    # Monte-Carlo noise: allow 3-sigma slack on 4000 trials
    sigma = np.sqrt(np.maximum(bound * (1 - bound), 1e-4) / 4000)
    assert np.all(emp <= bound + 3 * sigma), (emp, bound)


def test_exact_p_below_lemma1_bound():
    """The exact product form is tighter than (or equal to) the Lemma-1
    bound, which replaces every lambda_u by the uniform lower bound T/m."""
    cfg = _cfg(U=20, L=12)
    T_d, m = 7.0, 1.0
    lam = poisson_rates(T_d, m, jnp.asarray(cfg.P), jnp.asarray(cfg.B))
    exact = np.asarray(exact_p_layers(lam, cfg.L))
    bound = np.asarray(p_no_contributor(cfg.L, jnp.float32(T_d / m), cfg.U))
    assert np.all(exact <= bound + 1e-6)


def test_empirical_matches_exact_p():
    cfg = _cfg(U=10, L=6, seed=7)
    T_d, m = 6.0, 1.5
    emp = simulate_p_empirical(T_d, m, cfg, n_trials=8000, seed=5)
    lam = poisson_rates(T_d, m, jnp.asarray(cfg.P), jnp.asarray(cfg.B))
    exact = np.asarray(exact_p_layers(lam, cfg.L))
    sigma = np.sqrt(np.maximum(exact * (1 - exact), 1e-4) / 8000)
    assert np.all(np.abs(emp - exact) <= 4 * sigma + 5e-3), (emp, exact)
