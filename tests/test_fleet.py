"""Fleet subsystem: profiles, availability, cohort sampling, chunked
aggregation equivalence, and an end-to-end 200-device run_fleet smoke."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FleetConfig
from repro.core.aggregation import aggregate_grads, aggregate_grads_chunk
from repro.core.types import AnalysisConfig
from repro.data.synthetic import make_image_dataset
from repro.fleet.availability import (AVAILABILITY, AlwaysOn, Bernoulli,
                                      Diurnal, Markov, make_availability)
from repro.fleet.cohort import cohort_view, sample_cohort
from repro.fleet.engine import partition_fleet, reference_config, run_fleet
from repro.fleet.profiles import (PRESETS, fleet_from_config, load_mobiperf,
                                  load_trace, make_fleet, save_trace)
from repro.models.paper_models import make_mlp

FIXTURES = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "fixtures")


# ---------------------------------------------------------------------------
# profiles
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("preset", sorted(PRESETS))
def test_preset_sampling_deterministic_in_seed(preset):
    f1 = make_fleet(preset, 257, seed=3)
    f2 = make_fleet(preset, 257, seed=3)
    f3 = make_fleet(preset, 257, seed=4)
    assert f1.size == 257
    np.testing.assert_array_equal(f1.P, f2.P)
    np.testing.assert_array_equal(f1.B, f2.B)
    np.testing.assert_array_equal(f1.tier, f2.tier)
    assert not np.array_equal(f1.P, f3.P)
    assert float(f1.P.min()) > 0 and float(f1.B.min()) > 0
    assert set(np.unique(f1.tier)) <= {0, 1, 2}


def test_preset_shapes_differ():
    """The presets describe genuinely different populations."""
    lt = make_fleet("longtail-mobile", 2000, seed=0)
    dc = make_fleet("datacenter", 2000, seed=0)
    # datacenter: fast and tight; longtail: slower median, huge spread
    assert np.median(dc.P) > 3 * np.median(lt.P)
    assert (lt.P.max() / lt.P.min()) > 10 * (dc.P.max() / dc.P.min())
    assert dc.B.mean() < lt.B.mean()


def test_trace_roundtrip(tmp_path):
    fleet = make_fleet("bimodal-edge", 50, seed=1)
    path = os.path.join(tmp_path, "trace.json")
    save_trace(fleet, path)
    loaded = load_trace(path)
    np.testing.assert_allclose(loaded.P, fleet.P, rtol=1e-6)
    np.testing.assert_allclose(loaded.B, fleet.B, rtol=1e-6)
    np.testing.assert_array_equal(loaded.tier, fleet.tier)
    # FleetConfig trace_path routes through load_trace
    fc = FleetConfig(trace_path=path)
    np.testing.assert_allclose(fleet_from_config(fc).P, fleet.P, rtol=1e-6)


# ---------------------------------------------------------------------------
# availability
# ---------------------------------------------------------------------------

def _mean_rate(model, rounds=300):
    model.reset()
    return np.mean([model.step(t).mean() for t in range(rounds)])


def test_always_on():
    m = AlwaysOn(100)
    assert m.step(0).all() and m.step(7).all()


def test_bernoulli_respects_rate():
    m = Bernoulli(400, seed=0, rate=0.7)
    assert abs(_mean_rate(m) - 0.7) < 0.03


def test_diurnal_oscillates_around_mean():
    m = Diurnal(400, seed=0, mean=0.6, amplitude=0.35, period=12.0)
    assert abs(_mean_rate(m, rounds=240) - 0.6) < 0.04
    # with a shared phase the wave must actually swing
    m2 = Diurnal(400, seed=0, mean=0.6, amplitude=0.35, period=12.0)
    m2.phase[:] = 0.0
    per_round = [m2.step(t).mean() for t in range(12)]
    assert max(per_round) - min(per_round) > 0.4


def test_markov_stationary_rate_and_stickiness():
    m = Markov(500, seed=0, p_off_to_on=0.3, p_on_to_off=0.1)
    assert abs(m.stationary - 0.75) < 1e-9
    assert abs(_mean_rate(m) - 0.75) < 0.04
    # sticky: consecutive states agree far more often than iid draws would
    m.reset()
    prev = m.step(0)
    agrees = []
    for t in range(1, 50):
        cur = m.step(t)
        agrees.append(np.mean(cur == prev))
        prev = cur
    assert np.mean(agrees) > 0.8


def test_availability_deterministic_after_reset():
    for name in AVAILABILITY:
        m = make_availability(name, 64, seed=5)
        seq1 = [m.step(t).copy() for t in range(5)]
        m.reset()
        seq2 = [m.step(t).copy() for t in range(5)]
        for a, b in zip(seq1, seq2):
            np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# cohort sampling
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", ["uniform", "power-of-choice",
                                      "stratified"])
def test_cohort_exactly_U_distinct_available(strategy):
    fleet = make_fleet("longtail-mobile", 300, seed=0)
    rng = np.random.default_rng(0)
    avail = np.zeros(300, bool)
    avail[rng.choice(300, 150, replace=False)] = True
    idx = sample_cohort(np.random.default_rng(1), avail, fleet, 32, strategy)
    assert len(idx) == 32
    assert len(np.unique(idx)) == 32
    assert avail[idx].all()


def test_cohort_degrades_when_few_available():
    fleet = make_fleet("uniform", 100, seed=0)
    avail = np.zeros(100, bool)
    avail[:7] = True
    idx = sample_cohort(np.random.default_rng(0), avail, fleet, 32)
    assert sorted(idx.tolist()) == list(range(7))
    assert len(sample_cohort(np.random.default_rng(0),
                             np.zeros(100, bool), fleet, 32)) == 0


def test_power_of_choice_prefers_fast_devices():
    fleet = make_fleet("longtail-mobile", 500, seed=0)
    avail = np.ones(500, bool)
    rng1, rng2 = np.random.default_rng(0), np.random.default_rng(0)
    uni = sample_cohort(rng1, avail, fleet, 32, "uniform")
    poc = sample_cohort(rng2, avail, fleet, 32, "power-of-choice")
    assert fleet.P[poc].mean() > fleet.P[uni].mean()


def test_stratified_covers_tiers():
    fleet = make_fleet("uniform", 300, seed=0)
    avail = np.ones(300, bool)
    idx = sample_cohort(np.random.default_rng(0), avail, fleet, 30,
                        "stratified")
    assert set(np.unique(fleet.tier[idx])) == set(np.unique(fleet.tier))


def test_cohort_view_rederives_config():
    fleet = make_fleet("bimodal-edge", 200, seed=0)
    base = AnalysisConfig.default(U=16, L=4, R=8, T_max=16.0)
    idx = np.arange(10, 26)
    view = cohort_view(base, fleet, idx)
    assert view.U == 16
    np.testing.assert_array_equal(view.P, fleet.P[idx])
    np.testing.assert_array_equal(view.B, fleet.B[idx])
    assert view.R == base.R and view.T_max == base.T_max


# ---------------------------------------------------------------------------
# chunked aggregation == monolithic aggregation
# ---------------------------------------------------------------------------

def test_chunked_aggregation_matches_monolithic():
    U, L, F, C = 24, 5, 7, 8
    key = jax.random.PRNGKey(0)
    g = jax.random.normal(key, (U, L, F))
    mask = (jax.random.uniform(jax.random.PRNGKey(1), (U, L)) > 0.4
            ).astype(jnp.float32)
    p = jnp.full((L,), 0.1)
    ids = {"w": jnp.arange(L)}
    ref = aggregate_grads({"w": g}, ids, mask, p)["w"]
    counts = mask.sum(0)
    agg = None
    for c0 in range(0, U, C):
        part = aggregate_grads_chunk({"w": g[c0:c0 + C]}, ids,
                                     mask[c0:c0 + C], p, counts)["w"]
        agg = part if agg is None else agg + part
    np.testing.assert_allclose(np.asarray(agg), np.asarray(ref), rtol=1e-5,
                               atol=1e-6)


# ---------------------------------------------------------------------------
# end-to-end fleet run
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def fleet_setup():
    x_tr, y_tr, x_te, y_te = make_image_dataset(
        "mnist", n_train=1200, n_test=300, seed=0, noise_std=1.0)
    fleet = make_fleet("longtail-mobile", 200, seed=0)
    data = partition_fleet(x_tr, y_tr, x_te, y_te, 200, alpha=0.5, seed=0)
    return fleet, data


def test_run_fleet_smoke_200_devices(fleet_setup):
    fleet, data = fleet_setup
    model = make_mlp()
    avail = make_availability("diurnal", 200, seed=0, mean=0.7,
                              amplitude=0.25, period=8.0)
    _, hist = run_fleet(model, fleet, avail, data, method="adel", rounds=6,
                        cohort_size=16, chunk_size=8, solver_steps=300,
                        seed=0)
    assert len(hist.accuracy) >= 4
    assert len(hist.available) == len(hist.accuracy)
    assert all(0 < a <= 200 for a in hist.available)
    # learning signal: train loss decreases over the run
    assert hist.train_loss[-1] < hist.train_loss[0], hist.train_loss
    # simulated clock respects the budget
    assert hist.times[-1] <= 6 * model.L * 0.5 * 1.001
    assert hist.method == "fleet-adel"


def test_run_fleet_baseline_and_reduced_cohort(fleet_setup):
    """salf + single-chunk fast path (cohort == chunk) + rounds where
    availability < cohort_size still execute."""
    fleet, data = fleet_setup
    model = make_mlp()
    avail = make_availability("bernoulli", 200, seed=1, rate=0.06)  # ~12 up
    _, hist = run_fleet(model, fleet, avail, data, method="salf", rounds=3,
                        cohort_size=16, chunk_size=16, seed=0)
    assert len(hist.accuracy) >= 1
    assert hist.method == "fleet-salf"


def test_run_fleet_backend_equivalence(fleet_setup):
    """The same fleet run under dense vs chunked execution: identical clock,
    near-identical learning trajectory (float summation order only)."""
    fleet, data = fleet_setup
    model = make_mlp()
    hists = {}
    for backend in ("dense", "chunked"):
        avail = make_availability("bernoulli", 200, seed=2, rate=0.5)
        _, hists[backend] = run_fleet(model, fleet, avail, data,
                                      method="salf", rounds=4,
                                      cohort_size=12, chunk_size=5,
                                      backend=backend, seed=0)
    a, b = hists["dense"], hists["chunked"]
    assert a.rounds == b.rounds and a.available == b.available
    np.testing.assert_allclose(a.times, b.times, rtol=1e-6)
    np.testing.assert_allclose(a.accuracy, b.accuracy, atol=0.015)


def test_run_fleet_heterofl_width_masks(fleet_setup):
    """HeteroFL now runs at fleet scale: per-cohort width ratios flow
    through the chunked backend's width-overlap mean."""
    fleet, data = fleet_setup
    model = make_mlp()
    avail = make_availability("markov", 200, seed=0, p_off_to_on=0.4,
                              p_on_to_off=0.1)
    _, hist = run_fleet(model, fleet, avail, data, method="heterofl",
                        rounds=6, cohort_size=16, chunk_size=8, seed=0,
                        eta0=1.0)
    assert hist.method == "fleet-heterofl"
    assert len(hist.accuracy) >= 3
    assert hist.train_loss[-1] < hist.train_loss[0], hist.train_loss


def test_heterofl_scenario_registered():
    from repro.fleet.scenarios import SCENARIOS
    scn = SCENARIOS["bimodal-edge-heterofl"]
    assert scn.method == "heterofl"
    assert scn.fleet.preset == "bimodal-edge"


def test_load_mobiperf_fixture():
    """MobiPerf-style logs import as a Fleet: one device per device_id,
    medians over repeated measurements, CPU->P / network->B / RAM->tier."""
    path = os.path.join(FIXTURES, "mobiperf_sample.json")
    fleet = load_mobiperf(path)
    assert fleet.size == 6                       # distinct device_ids
    assert fleet.name == "mobiperf"
    assert (fleet.P > 0).all() and (fleet.B > 0).all()
    devs = sorted(["pixel-3", "galaxy-s4", "moto-g", "nexus-7",
                   "oneplus-one", "iphone-6"])
    # devices are ordered by sorted id; pixel-3 (2.5 GHz x 8) is fastest
    pix = devs.index("pixel-3")
    assert fleet.P[pix] == fleet.P.max()
    # the big-RAM device lands in the top tier present
    assert fleet.tier[pix] == fleet.tier.max()
    # galaxy-s4's B uses the MEDIAN of its two rtt/throughput probes:
    # worse link than pixel-3's
    assert fleet.B[devs.index("galaxy-s4")] > fleet.B[pix]
    # nexus-7 reported no throughput: worst-observed-link fallback puts it
    # among the slowest links
    assert fleet.B[devs.index("nexus-7")] >= np.median(fleet.B)
    # deterministic: importing twice gives identical fleets
    f2 = load_mobiperf(path)
    np.testing.assert_array_equal(fleet.P, f2.P)
    np.testing.assert_array_equal(fleet.B, f2.B)
    # importable fleets drive the planner like any preset
    ref = reference_config(fleet, U=4, L=3, R=4, T_max=12.0)
    assert ref.U == 4 and (np.diff(ref.P) >= 0).all()


def test_run_fleet_lm_task():
    """LM workloads run against the fleet engine via the task adapters:
    token-row shards + make_lm_model + lm_eval_metrics."""
    from repro.configs import get_config
    from repro.fl.tasks import lm_eval_metrics, lm_fleet_data, make_lm_model

    n = 24
    cfg = get_config("qwen1.5-4b").reduced()
    model = make_lm_model(cfg)
    data = lm_fleet_data(cfg, n, seq=16, rows_per_device=8, seed=0)
    fleet = make_fleet("uniform", n, seed=0)
    avail = make_availability("bernoulli", n, seed=0, rate=0.8)
    _, hist = run_fleet(model, fleet, avail, data, method="adel", rounds=3,
                        cohort_size=6, chunk_size=3, solver_steps=150,
                        seed=0, s_max=6, eval_metrics=lm_eval_metrics)
    assert hist.method == "fleet-adel"
    assert len(hist.train_loss) == 3
    assert len(hist.available) == 3
    # token CE starts near ln(vocab) and never degenerates
    assert 0 < hist.train_loss[-1] < 8.0


def test_reference_config_spans_fleet():
    fleet = make_fleet("longtail-mobile", 500, seed=0)
    ref = reference_config(fleet, U=32, L=4, R=10, T_max=20.0)
    assert ref.U == 32 and ref.P.shape == (32,)
    # quantile-spaced: planning cohort spans the population's spread
    assert ref.P.min() <= np.quantile(fleet.P, 0.1)
    assert ref.P.max() >= np.quantile(fleet.P, 0.9)
    assert (np.diff(ref.P) >= 0).all()
