"""Lemma 3 (bounded variance): E||w~ - w||^2 <= eta^2 G^2 4U/(U-1) *
sum_l (1 + Q^U)/(1 - 5 Q^U) for a single round, Monte-Carlo."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import aggregate_grads
from repro.core.cost import c_term
from repro.core.straggler import contribution_mask, exact_p_layers, sample_depths
from repro.core.types import AnalysisConfig


def test_variance_bound_single_round():
    U, L, F = 12, 6, 16
    eta = 0.1
    key = jax.random.PRNGKey(0)
    # gradients with ||g_u||^2 <= G^2 (unit-norm rows scaled)
    g = jax.random.normal(key, (U, L, F))
    g = g / jnp.linalg.norm(g.reshape(U, -1), axis=1)[:, None, None]
    G2 = 1.0

    T_d, m = 8.0, 1.0
    lam_uniform = jnp.full((U,), T_d / m)          # B1 with equal rates
    p = exact_p_layers(lam_uniform, L)
    assert float(p[0]) < 0.2, "test setup must satisfy p_t^1 < 0.2"

    fedavg = g.mean(0)
    n = 4000
    keys = jax.random.split(jax.random.PRNGKey(7), n)

    def one(k):
        z = sample_depths(k, lam_uniform)
        mask = contribution_mask(z, L)
        agg = aggregate_grads({"w": g}, {"w": jnp.arange(L)}, mask, p)["w"]
        d = (agg - fedavg) * eta
        return jnp.sum(d * d)

    var = float(jax.vmap(one)(keys).mean())

    cfgT = AnalysisConfig(U=U, L=L, R=1, T_max=T_d, eta=np.asarray([eta]),
                          rho_c=0.1, rho_s=1.0, sigma2=np.ones(U),
                          G2=G2, het_gap=0.0, P=np.ones(U),
                          B=np.zeros(U))
    # Lemma-3 bound (C_t already includes G^2 4U/(U-1) sum_l ...)
    bound = eta ** 2 * float(c_term(jnp.asarray([T_d], jnp.float32),
                                    jnp.float32(m), cfgT)[0])
    assert var <= bound, (var, bound)
    assert var > 0.0


def test_variance_decreases_with_deadline():
    """Longer deadlines (relative to m) must shrink the truncation variance
    term C_t — the core scheduling trade-off."""
    U, L = 10, 8
    cfgT = AnalysisConfig(U=U, L=L, R=3, T_max=100.0,
                          eta=np.full(3, 0.1), rho_c=0.1, rho_s=1.0,
                          sigma2=np.ones(U), G2=1.0, het_gap=0.0,
                          P=np.ones(U), B=np.zeros(U))
    # deadlines in the regime where truncation actually binds (T/m ~ L):
    T = jnp.asarray([9.0, 7.0, 5.5], jnp.float32)
    c = np.asarray(c_term(T, jnp.float32(1.0), cfgT))
    assert c[0] < c[1] < c[2]
