"""Online re-planning (repro.core.replan): warm-start fidelity, re-solved
tail feasibility (nonincreasing / budget-exact / p_t^1 <= 0.2), trigger
behavior, the availability estimators behind the population view, and
``replan="never"`` bit-for-bit equivalence with the static runtime on all
three execution backends."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.baselines import make_policy
from repro.core.replan import (ReplanConfig, Replanner, make_replan,
                               remaining_horizon)
from repro.core.scheduler import (_default_m_min, _theta_to_Tm, _x_min,
                                  invert_schedule, solve_adam)
from repro.core.types import AnalysisConfig
from repro.data.synthetic import make_image_dataset
from repro.fl.partition import dirichlet_partition, stack_clients
from repro.fl.server import run_federated
from repro.fleet.availability import make_availability
from repro.fleet.engine import partition_fleet, run_fleet
from repro.fleet.profiles import make_fleet
from repro.models.paper_models import make_mlp


@pytest.fixture(scope="module")
def cfg():
    return AnalysisConfig.default(U=10, L=8, R=12, T_max=120.0, seed=1)


@pytest.fixture(scope="module")
def schedule(cfg):
    return solve_adam(cfg, steps=600)


# ---------------------------------------------------------------------------
# warm start
# ---------------------------------------------------------------------------

def test_invert_schedule_roundtrip(cfg, schedule):
    """theta = invert(T, m) reproduces (T, m) exactly under the solver's
    parameterization — the warm start begins at the incumbent tail."""
    theta = invert_schedule(cfg, schedule.T, schedule.m)
    T2, m2 = _theta_to_Tm(theta, cfg, _default_m_min(cfg), _x_min(cfg))
    np.testing.assert_allclose(np.asarray(T2), schedule.T, rtol=1e-5)
    assert abs(float(m2) - schedule.m) < 1e-5 * max(schedule.m, 1.0)


def test_warm_start_matches_cold_start(cfg, schedule):
    """A few hundred warm-started steps reach the 3000-step cold solve."""
    t = 5
    rem = remaining_horizon(cfg, t, float(schedule.T[t:].sum()), cfg.eta[t:])
    theta0 = invert_schedule(rem, schedule.T[t:], schedule.m)
    warm = solve_adam(rem, steps=300, theta0=theta0)
    cold = solve_adam(rem, steps=3000)
    assert warm.objective <= cold.objective * 1.01, \
        (warm.objective, cold.objective)


# ---------------------------------------------------------------------------
# re-solved tail feasibility (Lemma-3 construction preserved)
# ---------------------------------------------------------------------------

def test_replanned_tail_feasible(cfg, schedule):
    policy = make_policy("adel", cfg, schedule=schedule)
    rp = Replanner(ReplanConfig(trigger="every-k", steps=250), policy,
                   cfg.R, cfg.eta)
    t = 4
    elapsed = float(schedule.T[:t].sum())
    budget_left = cfg.T_max - elapsed
    ev = rp.replan(t, budget_left, reachable=cfg.U)
    tail = np.asarray(ev.T_tail)
    assert tail.shape == (cfg.R - t,)
    # nonincreasing, positive, budget used exactly
    assert np.all(tail > 0)
    assert np.all(np.diff(tail) <= 1e-5)
    np.testing.assert_allclose(tail.sum(), budget_left, rtol=1e-4)
    # spliced schedule: history head untouched, new tail live, p1 capped
    sch = policy.schedule
    np.testing.assert_array_equal(sch.T[:t], schedule.T[:t])
    np.testing.assert_allclose(sch.T[t:], tail, rtol=1e-6)
    assert np.all(sch.p1[t:] < 0.2 + 1e-6)
    assert sch.solver.endswith("-replan")
    assert rp.events == [ev]


def test_replan_view_tail_feasible_under_shrunken_fleet():
    """Fleet-style view with a U_round forecast: the re-solved tail keeps
    the Lemma-3 feasibility construction (budget exact, nonincreasing,
    p_t^1 <= 0.2 at the SMALLEST forecast cohort)."""
    cfg = AnalysisConfig.default(U=16, L=6, R=10, T_max=60.0, seed=0)
    schedule = solve_adam(cfg, steps=400)
    policy = make_policy("adel", cfg, schedule=schedule)
    rp = Replanner(ReplanConfig(trigger="drift", steps=250), policy,
                   cfg.R, cfg.eta)
    t = 3
    budget_left = float(schedule.T[t:].sum())
    u_fore = np.asarray([16, 9, 4, 2, 2, 5, 12], np.float32)
    view = dataclasses.replace(
        cfg, R=cfg.R - t, T_max=budget_left, eta=cfg.eta[t:],
        U_round=u_fore)
    ev = rp.replan(t, budget_left, reachable=5, view=view)
    tail = np.asarray(ev.T_tail)
    assert np.all(np.diff(tail) <= 1e-5)
    np.testing.assert_allclose(tail.sum(), budget_left, rtol=1e-4)
    assert np.all(np.asarray(policy.schedule.p1[t:]) < 0.2 + 1e-6)
    assert ev.U_est == view.U and ev.reachable == 5


# ---------------------------------------------------------------------------
# triggers
# ---------------------------------------------------------------------------

def test_make_replan_normalization():
    assert make_replan(None) is None
    assert make_replan("drift").trigger == "drift"
    rc = ReplanConfig(trigger="every-k", every=7)
    assert make_replan(rc) is rc
    with pytest.raises(ValueError):
        ReplanConfig(trigger="sometimes")
    with pytest.raises(TypeError):
        make_replan(3)


def test_should_replan_triggers(cfg, schedule):
    policy = make_policy("adel", cfg, schedule=schedule)
    ek = Replanner(ReplanConfig(trigger="every-k", every=3), policy,
                   cfg.R, cfg.eta)
    assert not ek.should_replan(0, 100)          # round-0 plan reference
    fired = [t for t in range(1, cfg.R) if ek.should_replan(t, 100)]
    assert fired == [3, 6, 9]                    # R-1=11 past min_rounds_left

    dr = Replanner(ReplanConfig(trigger="drift", drift_threshold=0.25),
                   policy, cfg.R, cfg.eta)
    assert not dr.should_replan(0, 200)          # sets the reference
    assert not dr.should_replan(1, 180)          # -10%: below threshold
    assert dr.should_replan(2, 120)              # -40%: drift
    assert not dr.should_replan(cfg.R - 1, 10)   # tail too short to re-plan


def test_replanner_requires_schedule_policy(cfg):
    with pytest.raises(ValueError, match="adel"):
        Replanner(ReplanConfig(trigger="drift"),
                  make_policy("salf", cfg), cfg.R, cfg.eta)


# ---------------------------------------------------------------------------
# availability estimators (the population side of the re-plan view)
# ---------------------------------------------------------------------------

def test_expected_reachable_estimators():
    always = make_availability("always-on", 50)
    np.testing.assert_allclose(always.expected_reachable(0, 3), [50, 50, 50])

    bern = make_availability("bernoulli", 400, seed=0, rate=0.7)
    np.testing.assert_allclose(bern.expected_reachable(5, 2), [280, 280])

    diu = make_availability("diurnal", 300, seed=0, mean=0.5, amplitude=0.4,
                            period=8.0, phase_spread=0.3)
    exp = diu.expected_reachable(0, 8)
    assert exp.max() > 1.5 * exp.min()           # synchronized wave swings
    # the forecast tracks the realized counts in expectation
    real = np.asarray([diu.step(t).sum() for t in range(8)])
    assert np.corrcoef(exp, real)[0, 1] > 0.9

    mk = make_availability("markov", 500, seed=0, p_off_to_on=0.3,
                           p_on_to_off=0.1)
    mk.step(0)
    now = mk.expected_reachable(0, 1)[0]
    assert now == mk.state.sum()                 # k=0: the drawn state
    far = mk.expected_reachable(0, 40)[-1]
    assert abs(far - 0.75 * 500) < 1.0           # k->inf: stationary rate


def test_diurnal_phase_spread_controls_population_swing():
    washed = make_availability("diurnal", 400, seed=0, mean=0.5,
                               amplitude=0.4, period=8.0)
    synced = make_availability("diurnal", 400, seed=0, mean=0.5,
                               amplitude=0.4, period=8.0, phase_spread=0.3)
    swing = lambda m: (lambda e: float(e.max() - e.min()))(
        m.expected_reachable(0, 8))
    assert swing(synced) > 4 * swing(washed)


# ---------------------------------------------------------------------------
# runtime integration
# ---------------------------------------------------------------------------

R = 5
U = 8


@pytest.fixture(scope="module")
def fl_setup():
    x_tr, y_tr, x_te, y_te = make_image_dataset(
        "mnist", n_train=600, n_test=200, seed=0, noise_std=1.0)
    parts = dirichlet_partition(y_tr, U, alpha=0.5, seed=0)
    cx, cy, counts = stack_clients(x_tr, y_tr, parts)
    model = make_mlp()
    cfg = AnalysisConfig.default(U=U, L=model.L, R=R, T_max=R * model.L * 0.5,
                                 eta0=2.0, seed=0)
    data = (jnp.asarray(cx), jnp.asarray(cy), jnp.asarray(counts),
            jnp.asarray(x_te), jnp.asarray(y_te))
    schedule = solve_adam(cfg, steps=150)
    return model, cfg, data, schedule


def _run_static(fl_setup, backend, replan):
    model, cfg, data, schedule = fl_setup
    policy = make_policy("adel", cfg, schedule=schedule)
    _, hist = run_federated(model, policy, cfg, *data,
                            key=jax.random.PRNGKey(0), backend=backend,
                            chunk_size=3 if backend == "chunked" else None,
                            replan=replan)
    return hist


@pytest.mark.parametrize("backend", ["dense", "chunked", "shard_map"])
def test_replan_never_bit_for_bit(fl_setup, backend):
    """trigger="never" must not perturb the run AT ALL: identical History
    (every field, exact floats) to a run without the replan machinery."""
    base = _run_static(fl_setup, backend, None)
    never = _run_static(fl_setup, backend, ReplanConfig())
    assert base.as_dict() == never.as_dict()
    assert never.replans == []


def test_every_k_static_run_respects_budget(fl_setup):
    model, cfg, data, schedule = fl_setup
    hist = _run_static(fl_setup, "dense",
                       ReplanConfig(trigger="every-k", every=2, steps=150))
    assert len(hist.replans) >= 1
    for ev in hist.replans:
        assert set(ev) >= {"round", "reachable", "U_est", "T_tail", "m",
                           "objective", "budget_left"}
        assert ev["reachable"] == U            # static population
    # the re-solved schedule still lands exactly on the R2 budget
    np.testing.assert_allclose(hist.times[-1], cfg.T_max, rtol=1e-4)


def test_skipped_round_budget_credited(fl_setup):
    """An empty-cohort round spends nothing: its planned deadline is
    credited back (zeroed in the schedule's history head) and a re-solve
    is FORCED at the next executed round, whose budget_left then includes
    the credit — regardless of the configured trigger cadence."""
    from repro.fl.runtime import RoundRuntime, StaticCohortSource

    model, cfg, data, schedule = fl_setup
    cx, cy, counts, x_te, y_te = data
    policy = make_policy("adel", cfg, schedule=schedule)
    planned = np.asarray(schedule.T).copy()

    class SkippySource(StaticCohortSource):
        def round_cohort(self, t):
            return None if t == 1 else super().round_cohort(t)

    runtime = RoundRuntime(model, policy)
    _, hist = runtime.run(
        SkippySource(cx, cy, counts), rounds=cfg.R, T_max=cfg.T_max,
        eta=cfg.eta, s_max=16, key=jax.random.PRNGKey(0),
        test_x=x_te, test_y=y_te,
        replan=ReplanConfig(trigger="drift", drift_threshold=10.0,
                            steps=120))
    # the reachable count never moves and the drift threshold is huge, so
    # the ONLY re-solve is the skip-forced one at the next executed round
    assert len(hist.replans) == 1
    ev = hist.replans[0]
    assert ev["round"] == 2
    np.testing.assert_allclose(ev["skipped_credit"], planned[1], rtol=1e-6)
    # the spliced history head records that round 1 spent nothing
    assert float(policy.schedule.T[1]) == 0.0
    # the re-solved tail starts from the TRUE remaining budget (only round
    # 0's deadline was actually spent) and lands exactly on it
    np.testing.assert_allclose(ev["budget_left"], cfg.T_max - planned[0],
                               rtol=1e-5)
    np.testing.assert_allclose(np.sum(ev["T_tail"]), ev["budget_left"],
                               rtol=1e-4)


def test_fleet_drift_replan_records_and_respects_budget():
    n = 120
    fleet = make_fleet("longtail-mobile", n, seed=0)
    x_tr, y_tr, x_te, y_te = make_image_dataset(
        "mnist", n_train=800, n_test=200, seed=0, noise_std=1.0)
    data = partition_fleet(x_tr, y_tr, x_te, y_te, n, alpha=0.5, seed=0)
    avail = make_availability("diurnal", n, seed=0, mean=0.45, amplitude=0.4,
                              period=8.0, phase_spread=0.5)
    rounds = 8
    model = make_mlp()
    _, hist = run_fleet(model, fleet, avail, data, method="adel",
                        rounds=rounds, cohort_size=24, chunk_size=12,
                        solver_steps=200, seed=0,
                        replan=ReplanConfig(trigger="drift",
                                            drift_threshold=0.3, steps=150))
    assert len(hist.replans) >= 1
    for ev in hist.replans:
        assert 2 <= ev["U_est"] <= 24
        tail = np.asarray(ev["T_tail"])
        assert np.all(np.diff(tail) <= 1e-5)
        np.testing.assert_allclose(tail.sum(), ev["budget_left"], rtol=1e-4)
    # replanning must never overdraw the R2 budget
    assert hist.times[-1] <= rounds * model.L * 0.5 * 1.001


def test_fleet_replan_requires_adel():
    n = 60
    fleet = make_fleet("uniform", n, seed=0)
    x_tr, y_tr, x_te, y_te = make_image_dataset(
        "mnist", n_train=300, n_test=100, seed=0, noise_std=1.0)
    data = partition_fleet(x_tr, y_tr, x_te, y_te, n, alpha=None, seed=0)
    avail = make_availability("always-on", n)
    with pytest.raises(ValueError, match="adel"):
        run_fleet(make_mlp(), fleet, avail, data, method="salf", rounds=2,
                  cohort_size=8, replan="drift", seed=0)
