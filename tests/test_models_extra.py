"""Paper-model details: GroupNorm, zero-init heads, cost-probe math."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.nn import group_norm
from repro.models.paper_models import make_cnn, make_mlp, make_vgg


def test_group_norm_normalizes_groups():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 4, 4, 16)) * 5 + 3
    g = jnp.ones((16,))
    o = jnp.zeros((16,))
    y = group_norm(x, g, o, groups=4)
    yg = np.asarray(y).reshape(2, 4, 4, 4, 4)
    mu = yg.mean(axis=(1, 2, 4))
    sd = yg.std(axis=(1, 2, 4))
    np.testing.assert_allclose(mu, 0.0, atol=1e-4)
    np.testing.assert_allclose(sd, 1.0, atol=1e-3)


def test_group_norm_affine():
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 2, 8))
    y = group_norm(x, 2.0 * jnp.ones((8,)), 3.0 * jnp.ones((8,)), groups=2)
    y1 = group_norm(x, jnp.ones((8,)), jnp.zeros((8,)), groups=2)
    np.testing.assert_allclose(np.asarray(y), np.asarray(2.0 * y1 + 3.0),
                               rtol=1e-5)


@pytest.mark.parametrize("make", [make_mlp, make_cnn,
                                  lambda: make_vgg(11, width_scale=0.125)])
def test_zero_init_head_gives_log10_loss(make):
    model = make()
    params = model.init(jax.random.PRNGKey(0))
    shape = (8, 28, 28, 1) if model.name in ("mlp", "cnn") else (8, 32, 32, 3)
    x = jax.random.normal(jax.random.PRNGKey(1), shape)
    y = jnp.arange(8) % 10
    w = jnp.full((8,), 1.0 / 8)
    loss = float(model.loss(params, x, y, w))
    assert abs(loss - np.log(10.0)) < 1e-3, loss


def test_costprobe_linear_extrapolation():
    from repro.launch.costprobe import _lin2
    # exact recovery of rest + l * slope
    c2 = {"flops": 10.0 + 2 * 3.0, "bytes": 5.0 + 2 * 1.0, "coll": 2 * 4.0}
    c4 = {"flops": 10.0 + 4 * 3.0, "bytes": 5.0 + 4 * 1.0, "coll": 4 * 4.0}
    out = _lin2(c2, c4, 40)
    assert out["flops"] == pytest.approx(10.0 + 40 * 3.0)
    assert out["bytes"] == pytest.approx(5.0 + 40 * 1.0)
    assert out["coll"] == pytest.approx(40 * 4.0)


def test_vgg_width_masks_cover_norm_params():
    model = make_vgg(11, width_scale=0.125)
    params = model.init(jax.random.PRNGKey(0))
    masks = model.width_masks(params, np.asarray([0.5, 1.0]))
    # congruent trees: every param leaf has a mask leaf with a leading U dim
    jax.tree.map(lambda p, m: None, params, jax.tree.map(lambda m: m[0],
                                                         masks))
    lead = {m.shape[0] for m in jax.tree.leaves(masks)}
    assert lead == {2}
